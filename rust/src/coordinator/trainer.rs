//! The training loop: paper Alg 1 with pluggable inverse-update policy.
//!
//! One `Trainer` = one optimizer run. Per step:
//!   1. `train_step` artifact: loss, grads, K-factor statistics
//!   2. on stat steps (k % T_updt == 0): per-layer EA updates
//!      (work-stolen across layers), then the policy's decomposition ops
//!      (RSVD / Brand / correction / exact EVD) — executed inline, or
//!      submitted to the async preconditioner service (`precond`,
//!      DESIGN.md §9) when `TrainerCfg::precond` is set
//!   3. per-layer preconditioned step (artifact), BN/SGD for the rest
//!   4. global step clipping, weight decay, parameter update
//!   5. BN running-stat EA
//!
//! The rust side owns ALL state and randomness; python never runs here.
//! In service mode, randomness for decomposition ops is still drawn on
//! this thread at submission (see `OpRequest::prepare`), which is why
//! the service's sync mode bit-matches the inline path.
//!
//! Dense-kernel selection (`train --kernel`, DESIGN.md §16) is a
//! process-global set before the trainer is built; every `Mat` op on
//! both the inline and service paths dispatches through it, and because
//! the backends are bit-identical nothing here needs to carry it. The
//! resolved backend + per-kernel counters ride the run log via
//! [`ServiceRecord::kernel`](crate::metrics::ServiceRecord).

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::data::{Batch, Dataset};
use crate::linalg::Mat;
use crate::metrics::{EvalRecord, RunLog, ServiceRecord, TrainRecord};
use crate::model::{BnState, ParamStore};
use crate::obs::{ProbeRecorder, ProbeSample};
use crate::optim::factor::{FactorSnapshot, FactorState, OpRequest, Stat};
use crate::optim::{Algo, Hyper, LayerState, Policy};
use crate::optim::seng::SengState;
use crate::precond::{PrecondCfg, PrecondService};
use crate::runtime::{Manifest, Runtime, Value};
use crate::util::rng::{Rng, RngState};
use crate::util::threadpool;
use crate::util::timer::PhaseTimers;

#[derive(Clone, Debug)]
pub struct TrainerCfg {
    pub algo: Algo,
    pub hyper: Hyper,
    pub seed: u64,
    /// evaluate every `eval_every` epochs (1 = every epoch)
    pub eval_every: usize,
    /// SENG-specific (official defaults, appendix D)
    pub seng_damping: f32,
    pub seng_momentum: f32,
    pub seng_lr0: f32,
    pub seng_wd: f32,
    /// capture per-step grad/direction/stats of this layer (error probe)
    pub probe_layer: Option<String>,
    /// run decomposition updates through the async sharded
    /// preconditioner service (None = historical inline path)
    pub precond: Option<PrecondCfg>,
}

/// Per-step capture for the §4.2 error study.
#[derive(Clone, Debug)]
pub struct Capture {
    pub grad: Mat,
    pub dir: Mat,
    pub a_stat: Mat,
    pub g_stat: Mat,
    pub stat_step: bool,
}

impl Default for TrainerCfg {
    fn default() -> Self {
        TrainerCfg {
            algo: Algo::BKfac,
            hyper: Hyper::default(),
            seed: 42,
            eval_every: 1,
            seng_damping: 2.0,
            seng_momentum: 0.9,
            seng_lr0: 0.05,
            seng_wd: 1e-2,
            probe_layer: None,
            precond: None,
        }
    }
}

pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    pub cfg: TrainerCfg,
    pub policy: Policy,
    pub params: ParamStore,
    pub bn: BnState,
    pub layers: Vec<LayerState>,
    pub seng: SengState,
    pub rng: Rng,
    pub timers: PhaseTimers,
    pub step: usize,
    /// most recent probe capture (when cfg.probe_layer is set)
    pub last_capture: Option<Capture>,
    /// async preconditioner service (cfg.precond); factor shard i maps
    /// to layer i/2, side A (even) / G (odd)
    pub service: Option<PrecondService>,
    /// sampled inversion-error probes on installed decompositions
    /// (observation only — never touches the trainer RNG or trajectory)
    pub probe: ProbeRecorder,
    /// last published version installed per factor shard
    installed_versions: Vec<u64>,
    /// output index map for the train_step artifact
    out_idx: BTreeMap<String, usize>,
    /// output index map for train_step_light (None if not in manifest)
    out_idx_light: Option<BTreeMap<String, usize>>,
    /// names of fc layers with dropout, artifact input order
    dropout_layers: Vec<(String, f64, usize)>, // (name, p, d_in)
}

/// Result of a single optimizer step.
#[derive(Clone, Copy, Debug)]
pub struct StepStats {
    pub loss: f32,
    pub acc: f32,
}

/// The resumable half of a [`Trainer`] — everything the training
/// trajectory depends on, detached from the runtime/config/artifacts
/// (which are rebuilt from the manifest on restore). Serialized by
/// `server::ckpt`; restoring it continues the run bit-identically.
#[derive(Clone, Debug)]
pub struct TrainerState {
    pub step: usize,
    pub rng: RngState,
    /// parameter tensors by name (canonical `ParamStore` order)
    pub params: Vec<(String, Vec<f32>)>,
    pub bn_means: Vec<(String, Vec<f32>)>,
    pub bn_vars: Vec<(String, Vec<f32>)>,
    pub bn_initialized: bool,
    /// per-factor snapshots, `2*layer + {0=A, 1=G}` order
    pub factors: Vec<FactorSnapshot>,
    /// SENG running squared-gradient diagonals (empty for other algos)
    pub seng_diag: Vec<(String, Vec<f32>)>,
    /// SENG momentum velocity buffers (empty for other algos)
    pub seng_velocity: Vec<(String, Vec<f32>)>,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, cfg: TrainerCfg) -> Result<Trainer<'rt>> {
        let service = cfg.precond.as_ref().map(|pc| {
            PrecondService::new(pc.clone(), Self::factor_ids(&rt.manifest))
        });
        Self::with_service(rt, cfg, service)
    }

    /// Cell ids of the per-factor decomposition shards, in the order the
    /// trainer submits to them (`2*layer + {0=A, 1=G}`).
    pub fn factor_ids(manifest: &Manifest) -> Vec<String> {
        let mut ids = Vec::with_capacity(manifest.layers.len() * 2);
        for l in &manifest.layers {
            ids.push(l.factors[0].id.clone());
            ids.push(l.factors[1].id.clone());
        }
        ids
    }

    /// Build a trainer around an externally constructed preconditioner
    /// service — the multi-tenant server path, where the service is in
    /// shared mode over the server's worker pool. `service = None` is
    /// the historical inline decomposition path.
    pub fn with_service(
        rt: &'rt Runtime,
        cfg: TrainerCfg,
        service: Option<PrecondService>,
    ) -> Result<Trainer<'rt>> {
        let manifest = &rt.manifest;
        // loud cadence validation before Policy::new (which only
        // debug-asserts): a zero period reaching op_at divides by zero
        cfg.hyper
            .validate()
            .map_err(|e| anyhow::anyhow!("invalid hyper cadences: {e}"))?;
        // the auto policy engine lives in the host-session substrate
        // (server::session); the artifact-backed trainer runs fixed
        // algorithms only
        anyhow::ensure!(
            cfg.algo != crate::optim::Algo::Auto,
            "algo = auto needs a host session (serve); the trainer runs fixed algorithms"
        );
        let mut rng = Rng::new(cfg.seed);
        let params = ParamStore::init(manifest, &mut rng);
        let bn = BnState::new(manifest, 0.9);
        let policy = Policy::new(cfg.algo, cfg.hyper.clone());
        let mut layers = Vec::new();
        for l in &manifest.layers {
            let fa = l.factors[0].clone();
            let fg = l.factors[1].clone();
            let keep_a = policy.needs_gram(&fa);
            let keep_g = policy.needs_gram(&fg);
            layers.push(LayerState::new(
                l.clone(),
                FactorState::new(fa, keep_a),
                FactorState::new(fg, keep_g),
            ));
        }
        let train_spec = manifest
            .artifacts
            .get("train_step")
            .context("manifest missing train_step artifact")?;
        let out_names = train_spec
            .output_names
            .clone()
            .context("train_step artifact lacks output names")?;
        let out_idx = out_names
            .iter()
            .enumerate()
            .map(|(i, n)| (n.clone(), i))
            .collect();
        let out_idx_light = manifest
            .artifacts
            .get("train_step_light")
            .and_then(|a| a.output_names.as_ref())
            .map(|ns| {
                ns.iter()
                    .enumerate()
                    .map(|(i, n)| (n.clone(), i))
                    .collect()
            });
        let dropout_layers = manifest
            .layers
            .iter()
            .filter(|l| l.kind == "fc" && l.dropout > 0.0)
            .map(|l| (l.name.clone(), l.dropout, l.d_a - 1))
            .collect();
        if let Some(svc) = &service {
            anyhow::ensure!(
                svc.n_cells() == layers.len() * 2,
                "preconditioner service has {} cells, model needs {}",
                svc.n_cells(),
                layers.len() * 2
            );
        }
        let installed_versions = vec![0u64; layers.len() * 2];
        Ok(Trainer {
            rt,
            seng: SengState::new(cfg.seng_damping, cfg.seng_momentum),
            policy,
            params,
            bn,
            layers,
            rng,
            timers: PhaseTimers::new(),
            step: 0,
            last_capture: None,
            service,
            probe: ProbeRecorder::default(),
            installed_versions,
            out_idx,
            out_idx_light,
            dropout_layers,
            cfg,
        })
    }

    /// Pre-compile every artifact this run can touch, so timing loops
    /// measure execution, not first-call compilation.
    pub fn warmup(&self) -> Result<()> {
        let mut names: Vec<&str> = vec!["train_step", "eval_step"];
        for l in &self.rt.manifest.layers {
            names.extend(l.ops.values().map(|s| s.as_str()));
            for f in &l.factors {
                names.extend(f.ops.values().map(|s| s.as_str()));
            }
        }
        self.rt.warmup(&names)
    }

    fn out<'a>(&self, outs: &'a [Value], name: &str) -> &'a Value {
        &outs[*self
            .out_idx
            .get(name)
            .unwrap_or_else(|| panic!("train_step has no output '{name}'"))]
    }


    /// Execute one optimizer step on a batch. `epoch` drives schedules.
    pub fn train_step(&mut self, batch: &Batch, epoch: usize) -> Result<StepStats> {
        let k = self.step;
        let m = &self.rt.manifest;
        let b = m.config.batch;
        assert_eq!(batch.y.len(), b, "batch size mismatch");

        // ---- 1. forward/backward -------------------------------------
        let mut inputs = self.params.as_values();
        inputs.push(Value::T(
            batch.x.clone(),
            vec![b, m.config.image, m.config.image, m.config.channels],
        ));
        inputs.push(Value::I(batch.y.clone()));
        for (_, p, d_in) in &self.dropout_layers {
            let keep = 1.0 - *p as f32;
            let mut mask = vec![0.0f32; b * d_in];
            for v in mask.iter_mut() {
                if self.rng.next_f32() < keep {
                    *v = 1.0 / keep;
                }
            }
            inputs.push(Value::T(mask, vec![b, *d_in]));
        }
        // stat-skipping (§Perf): statistics are only consumed on stat
        // steps, so all other steps run the cheaper no-stats graph —
        // unless the algorithm needs per-step stats (SENG, Alg-8 apply)
        // or a probe wants per-step captures.
        let stat_step_pre = k % self.policy.hyper.t_updt == 0;
        let needs_stats_every_step = matches!(self.policy.algo, Algo::Seng)
            || self.policy.hyper.linear_apply
            || self.cfg.probe_layer.is_some();
        let use_light = self.out_idx_light.is_some()
            && !stat_step_pre
            && !needs_stats_every_step;
        let artifact = if use_light { "train_step_light" } else { "train_step" };
        let t0 = Instant::now();
        let outs = self.rt.exec(artifact, &inputs)?;
        self.timers.add(
            if use_light { "fwd_bwd_light" } else { "fwd_bwd" },
            t0.elapsed().as_secs_f64(),
        );
        // index map for the artifact actually executed (cloned: tiny, and
        // avoids holding an immutable self borrow across the &mut uses)
        let idx_map: BTreeMap<String, usize> = if use_light {
            self.out_idx_light.clone().expect("light artifact")
        } else {
            self.out_idx.clone()
        };
        fn pick<'a>(
            outs: &'a [Value],
            map: &BTreeMap<String, usize>,
            name: &str,
        ) -> &'a Value {
            &outs[*map
                .get(name)
                .unwrap_or_else(|| panic!("artifact has no output '{name}'"))]
        }
        fn grad_of(outs: &[Value], map: &BTreeMap<String, usize>, name: &str) -> Vec<f32> {
            match pick(outs, map, &format!("grad:{name}")) {
                Value::M(m) => m.data.clone(),
                Value::V(v) => v.clone(),
                other => panic!("grad:{name} unexpected value {other:?}"),
            }
        }

        let loss = pick(&outs, &idx_map, "loss").as_scalar();
        let n_correct = pick(&outs, &idx_map, "n_correct").as_scalar();

        // ---- 2. statistics + decomposition updates --------------------
        let rho = self.policy.hyper.rho;
        let stat_step = k % self.policy.hyper.t_updt == 0;
        if self.policy.algo.is_kfac_family() && stat_step {
            // bounded staleness: block only if a factor's oldest
            // unfinished decomposition fell too far behind (no-op inline
            // and in sync mode)
            if let Some(svc) = &self.service {
                let t0 = Instant::now();
                svc.enforce_staleness(k as u64);
                self.timers.add("svc_staleness_wait", t0.elapsed().as_secs_f64());
            }
            // gather this step's statistics (artifact outputs) per layer
            let mut stats: Vec<(Mat, Mat, bool)> = Vec::with_capacity(self.layers.len());
            for layer in &self.layers {
                let lname = &layer.spec.name;
                let a_stat = pick(&outs, &idx_map, &format!("stat:{lname}/A")).as_mat().clone();
                let g_stat = pick(&outs, &idx_map, &format!("stat:{lname}/G")).as_mat().clone();
                stats.push((a_stat, g_stat, layer.spec.kind == "conv"));
            }
            // EA updates are independent across layers and uneven in cost
            // (fc syrk vs conv axpy) — work-steal them across threads.
            // Concurrent rt.exec relies on Runtime's documented PJRT
            // thread-safety; the outer width is capped at 4 because the
            // host syrk fallback threads internally (linalg::gemm) and
            // nesting both at default_threads() would oversubscribe.
            let rt = self.rt;
            let n_layers = self.layers.len();
            let threads = threadpool::default_threads().min(n_layers.max(1)).min(4);
            let mut ea_results: Vec<Result<()>> = Vec::with_capacity(n_layers);
            let mut ea_timers = PhaseTimers::new();
            {
                let items: Vec<Mutex<(&mut LayerState, PhaseTimers, Result<()>)>> = self
                    .layers
                    .iter_mut()
                    .map(|l| Mutex::new((l, PhaseTimers::new(), Ok(()))))
                    .collect();
                threadpool::parallel_items(n_layers, threads, |i| {
                    let mut cell = items[i].lock().unwrap();
                    let (layer, timers, res) = &mut *cell;
                    let (a_stat, g_stat, kind_conv) = &stats[i];
                    let (sa, sg) = if *kind_conv {
                        (Stat::Gram(a_stat), Stat::Gram(g_stat))
                    } else {
                        (Stat::Raw(a_stat), Stat::Raw(g_stat))
                    };
                    *res = layer
                        .a
                        .stat_update(&sa, rho, Some(rt), timers)
                        .and_then(|()| layer.g.stat_update(&sg, rho, Some(rt), timers));
                });
                for item in items {
                    let (_, t, r) = item.into_inner().unwrap();
                    ea_timers.merge(&t);
                    ea_results.push(r);
                }
            }
            self.timers.merge(&ea_timers);
            for r in ea_results {
                r?;
            }
            // decomposition ops per policy: inline (historical path) or
            // submitted to the sharded service
            if self.service.is_some() {
                self.submit_ops(k, &stats)?;
            } else {
                for (li, (a_stat, g_stat, kind_conv)) in stats.iter().enumerate() {
                    let conv = *kind_conv;
                    let layer = &mut self.layers[li];
                    let op_a = self.policy.op_at(k, &layer.a.plan);
                    let op_g = self.policy.op_at(k, &layer.g.plan);
                    let raw_a = (!conv).then_some(a_stat);
                    let raw_g = (!conv).then_some(g_stat);
                    layer.a.run_op(
                        op_a,
                        raw_a,
                        rho,
                        &self.policy,
                        Some(self.rt),
                        &mut self.rng,
                        &mut self.timers,
                    )?;
                    layer.g.run_op(
                        op_g,
                        raw_g,
                        rho,
                        &self.policy,
                        Some(self.rt),
                        &mut self.rng,
                        &mut self.timers,
                    )?;
                }
            }
        }
        // pull the freshest complete decompositions the service published
        // (every step — async completions can land between stat steps)
        self.install_published(k as u64);

        // ---- 3. directions --------------------------------------------
        let alpha = self.lr(epoch);
        let phi = self.policy.hyper.phi_lambda(epoch);
        let mut directions: BTreeMap<String, Vec<f32>> = BTreeMap::new();
        match self.policy.algo {
            Algo::Sgd => {
                for name in self.params.names().to_vec() {
                    let g = grad_of(&outs, &idx_map, &name);
                    directions.insert(name, g);
                }
            }
            Algo::Seng => {
                for li in 0..self.layers.len() {
                    let spec = self.layers[li].spec.clone();
                    let grad = self
                        .out(&outs, &format!("grad:{}", spec.grad_param))
                        .as_mat()
                        .clone();
                    let dir = if spec.kind == "fc" {
                        let a_stat =
                            pick(&outs, &idx_map, &format!("stat:{}/A", spec.name)).as_mat();
                        let g_stat =
                            pick(&outs, &idx_map, &format!("stat:{}/G", spec.name)).as_mat();
                        self.timers.time("seng_fc", || {
                            self.seng.fc_direction(&grad, a_stat, g_stat).data
                        })
                    } else {
                        self.seng.diag_direction(&spec.grad_param, &grad.data)
                    };
                    let dir = self.seng.momentum_step(&spec.grad_param, &dir);
                    directions.insert(spec.grad_param.clone(), dir);
                }
                // BN params: diagonal scaling + momentum
                for name in self.params.names().to_vec() {
                    if directions.contains_key(&name) {
                        continue;
                    }
                    let g = grad_of(&outs, &idx_map, &name);
                    let dir = self.seng.diag_direction(&name, &g);
                    let dir = self.seng.momentum_step(&name, &dir);
                    directions.insert(name, dir);
                }
            }
            _ => {
                let exact = self.policy.algo == Algo::KfacExact;
                for li in 0..self.layers.len() {
                    let spec = self.layers[li].spec.clone();
                    let grad = self
                        .out(&outs, &format!("grad:{}", spec.grad_param))
                        .as_mat()
                        .clone();
                    let layer = &self.layers[li];
                    let dir = if layer.has_reps() {
                        let use_linear = self.policy.hyper.linear_apply
                            && spec.kind == "fc"
                            && self.policy.brand_managed(&layer.a.plan);
                        if use_linear {
                            let a_stat =
                                pick(&outs, &idx_map, &format!("stat:{}/A", spec.name)).as_mat();
                            let g_stat =
                                pick(&outs, &idx_map, &format!("stat:{}/G", spec.name)).as_mat();
                            layer
                                .linear_apply_step(
                                    a_stat,
                                    g_stat,
                                    phi,
                                    &self.policy.hyper,
                                    Some(self.rt),
                                    &mut self.timers,
                                )?
                                .data
                        } else {
                            layer
                                .precond_step(
                                    &grad,
                                    phi,
                                    &self.policy.hyper,
                                    exact,
                                    Some(self.rt),
                                    &mut self.timers,
                                )?
                                .data
                        }
                    } else {
                        grad.data.clone()
                    };
                    directions.insert(spec.grad_param.clone(), dir);
                }
                // BN params use plain SGD directions
                for name in self.params.names().to_vec() {
                    if directions.contains_key(&name) {
                        continue;
                    }
                    directions.insert(name.clone(), grad_of(&outs, &idx_map, &name));
                }
            }
        }

        // ---- 4. clip + apply -------------------------------------------
        let (alpha, wd) = match self.policy.algo {
            Algo::Seng => (
                self.cfg.seng_lr0 * (-6.0 * epoch as f32 / 75.0).exp(),
                self.cfg.seng_wd,
            ),
            _ => (alpha, self.policy.hyper.weight_decay),
        };
        let clip = self.policy.hyper.clip;
        let mut total: f64 = 0.0;
        for d in directions.values() {
            for v in d {
                total += (*v as f64 * alpha as f64).powi(2);
            }
        }
        let norm = total.sqrt() as f32;
        let scale = if self.policy.algo.is_kfac_family() && norm > clip {
            clip / norm
        } else {
            1.0
        };
        for (name, dir) in &directions {
            self.params.apply_step(name, dir, alpha * scale, wd);
        }

        // ---- probe capture ---------------------------------------------
        if let Some(pl) = self.cfg.probe_layer.clone() {
            let grad_name = format!("grad:{pl}/w");
            let grad = pick(&outs, &idx_map, &grad_name).as_mat().clone();
            let dir_data = directions
                .get(&format!("{pl}/w"))
                .expect("probe layer direction")
                .clone();
            let dir = Mat::from_vec(grad.rows, grad.cols, dir_data);
            self.last_capture = Some(Capture {
                grad,
                dir,
                a_stat: pick(&outs, &idx_map, &format!("stat:{pl}/A")).as_mat().clone(),
                g_stat: pick(&outs, &idx_map, &format!("stat:{pl}/G")).as_mat().clone(),
                stat_step,
            });
        }

        // ---- 5. BN running stats ---------------------------------------
        for l in &self.rt.manifest.layers.clone() {
            if l.kind == "conv" {
                let mean = pick(&outs, &idx_map, &format!("bn:{}/mean", l.name)).as_vec().to_vec();
                let var = pick(&outs, &idx_map, &format!("bn:{}/var", l.name)).as_vec().to_vec();
                self.bn.update(&l.name, &mean, &var);
            }
        }
        self.bn.mark_initialized();

        self.step += 1;
        Ok(StepStats {
            loss,
            acc: n_correct / b as f32,
        })
    }

    /// Submit this stat step's decomposition ops to the preconditioner
    /// service. Randomness is pre-sampled here (submitting thread), in
    /// exactly the order the inline path would draw it — the sync-mode
    /// bit-match invariant.
    fn submit_ops(&mut self, k: usize, stats: &[(Mat, Mat, bool)]) -> Result<()> {
        let svc = self
            .service
            .as_ref()
            .expect("submit_ops requires the service");
        let rho = self.policy.hyper.rho;
        for (li, (a_stat, g_stat, kind_conv)) in stats.iter().enumerate() {
            let conv = *kind_conv;
            for (fi, stat) in [a_stat, g_stat].into_iter().enumerate() {
                let fs = if fi == 0 {
                    &self.layers[li].a
                } else {
                    &self.layers[li].g
                };
                let op = self.policy.op_at(k, &fs.plan);
                let raw = (!conv).then_some(stat);
                if let Some(req) =
                    OpRequest::prepare(op, &fs.plan, fs.gram.as_ref(), raw, rho, &mut self.rng)
                {
                    svc.submit(2 * li + fi, req, k as u64, Some(self.rt), &mut self.timers)?;
                }
            }
        }
        Ok(())
    }

    /// Install the freshest complete decompositions the service has
    /// published into the per-layer factor states (no-op in inline mode).
    fn install_published(&mut self, step: u64) {
        let Some(svc) = self.service.as_ref() else {
            return;
        };
        // probe damping: the base of the paper's φ_λ schedule — the
        // probe needs a fixed regularizer, not the epoch-scheduled one
        let lambda = self.policy.hyper.phi_lambda(0);
        for li in 0..self.layers.len() {
            for fi in 0..2 {
                let idx = 2 * li + fi;
                let cell = svc.cell(idx);
                if cell.published_version() == self.installed_versions[idx] {
                    continue;
                }
                if let Some(snap) = cell.load_published() {
                    self.installed_versions[idx] = snap.version;
                    let staleness = step.saturating_sub(snap.step);
                    svc.note_install(staleness);
                    let layer = &mut self.layers[li];
                    let fs = if fi == 0 { &mut layer.a } else { &mut layer.g };
                    fs.rep = Some(snap.rep.clone());
                    // the op scheduled at the snapshot's production step
                    // is the op that produced it
                    let kind = self.policy.op_at(snap.step as usize, &fs.plan).kind_label();
                    self.probe.on_install(
                        idx,
                        &fs.plan.id,
                        kind,
                        staleness,
                        step,
                        fs.gram.as_ref(),
                        &snap.rep,
                        lambda,
                    );
                }
            }
        }
    }

    /// Recorded inversion-error probe samples (bounded window).
    pub fn probe_samples(&self) -> &[ProbeSample] {
        self.probe.samples()
    }

    /// Snapshot of the service counters for the run log (None inline).
    pub fn service_record(&self) -> Option<ServiceRecord> {
        self.service.as_ref().map(|svc| svc.record())
    }

    /// Block until every pending decomposition has been applied and
    /// install the results (no-op in inline mode). Surfaces worker errors.
    pub fn drain_service(&mut self) -> Result<()> {
        if let Some(svc) = self.service.as_ref() {
            svc.drain()?;
        }
        self.install_published(self.step as u64);
        Ok(())
    }

    /// Deterministic resident-memory estimate for the server's quota
    /// governor (DESIGN.md §13.2): parameter tensors plus per-factor
    /// resident state ([`FactorState::resident_f32s`] — shared with
    /// `HostSession::resident_bytes`, so host and model quotas agree on
    /// what "resident" means).
    pub fn resident_bytes(&self) -> u64 {
        let factors: usize = self
            .layers
            .iter()
            .map(|l| l.a.resident_f32s() + l.g.resident_f32s())
            .sum();
        ((self.params.n_params() + factors) * std::mem::size_of::<f32>()) as u64
    }

    /// Release the dominant resident buffers (per-factor EA Grams and
    /// low-rank reps) after the server's governor evicts this session —
    /// the model-session counterpart of
    /// `HostSession::release_resident`. The trainer must not be stepped
    /// afterwards.
    pub fn release_resident(&mut self) {
        for l in &mut self.layers {
            for f in [&mut l.a, &mut l.g] {
                f.gram = None;
                f.rep = None;
            }
        }
    }

    /// Non-blocking probe: would the next step's staleness enforcement
    /// pass without waiting? The multi-tenant server pauses the session
    /// when this is false instead of letting `train_step` block.
    pub fn staleness_ok(&self) -> bool {
        match &self.service {
            None => true,
            Some(svc) => svc.staleness_ok(self.step as u64),
        }
    }

    /// Extract the resumable state (see [`TrainerState`]). Pair with
    /// [`drain_service`](Self::drain_service) first so no decomposition
    /// is in flight.
    pub fn snapshot_state(&self) -> TrainerState {
        let params = self
            .params
            .names()
            .iter()
            .map(|n| (n.clone(), self.params.get(n).data().to_vec()))
            .collect();
        let bn_means = self
            .bn
            .means
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        let bn_vars = self
            .bn
            .vars
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        let mut factors = Vec::with_capacity(self.layers.len() * 2);
        for l in &self.layers {
            factors.push(l.a.snapshot());
            factors.push(l.g.snapshot());
        }
        let (seng_diag, seng_velocity) = self.seng.snapshot();
        TrainerState {
            step: self.step,
            rng: self.rng.state(),
            params,
            bn_means,
            bn_vars,
            bn_initialized: self.bn.initialized(),
            factors,
            seng_diag,
            seng_velocity,
        }
    }

    /// Restore a state captured by [`snapshot_state`](Self::snapshot_state)
    /// into a freshly constructed trainer (same manifest/config). If a
    /// service is attached, its cells must have been seeded BEFORE this
    /// call (`PrecondService::seed`) — install bookkeeping is re-synced
    /// here so seeded publications are not re-installed.
    pub fn restore_state(&mut self, st: TrainerState) -> Result<()> {
        anyhow::ensure!(
            st.factors.len() == self.layers.len() * 2,
            "state has {} factors, model needs {}",
            st.factors.len(),
            self.layers.len() * 2
        );
        self.step = st.step;
        self.rng = Rng::from_state(&st.rng);
        for (name, data) in &st.params {
            let t = self.params.get_mut(name);
            anyhow::ensure!(
                t.data().len() == data.len(),
                "param '{name}' length changed"
            );
            t.data_mut().copy_from_slice(data);
        }
        for (name, data) in &st.bn_means {
            let slot = self
                .bn
                .means
                .get_mut(name)
                .ok_or_else(|| anyhow::anyhow!("unknown bn layer '{name}'"))?;
            anyhow::ensure!(slot.len() == data.len(), "bn '{name}' length changed");
            slot.copy_from_slice(data);
        }
        for (name, data) in &st.bn_vars {
            let slot = self
                .bn
                .vars
                .get_mut(name)
                .ok_or_else(|| anyhow::anyhow!("unknown bn layer '{name}'"))?;
            anyhow::ensure!(slot.len() == data.len(), "bn '{name}' length changed");
            slot.copy_from_slice(data);
        }
        if st.bn_initialized {
            self.bn.mark_initialized();
        }
        let mut it = st.factors.into_iter();
        for l in self.layers.iter_mut() {
            l.a.restore(it.next().unwrap());
            l.g.restore(it.next().unwrap());
        }
        self.seng.restore(st.seng_diag, st.seng_velocity);
        // seeded publications are already reflected in the restored reps;
        // start install tracking from the current published versions
        if let Some(svc) = &self.service {
            for (i, v) in self.installed_versions.iter_mut().enumerate() {
                *v = svc.cell(i).published_version();
            }
        }
        Ok(())
    }

    fn lr(&self, epoch: usize) -> f32 {
        self.policy.hyper.lr(epoch)
    }

    /// Test-set evaluation with BN running stats.
    pub fn evaluate(&mut self, ds: &Dataset) -> Result<(f32, f32)> {
        let m = &self.rt.manifest;
        let b = m.config.batch;
        let mut loss_sum = 0.0f64;
        let mut correct = 0.0f64;
        let mut count = 0usize;
        for batch in ds.test_batches(b) {
            let mut inputs = self.params.as_values();
            inputs.extend(self.bn.as_values(m));
            inputs.push(Value::T(
                batch.x.clone(),
                vec![b, m.config.image, m.config.image, m.config.channels],
            ));
            inputs.push(Value::I(batch.y.clone()));
            let t0 = Instant::now();
            let outs = self.rt.exec("eval_step", &inputs)?;
            self.timers.add("eval", t0.elapsed().as_secs_f64());
            loss_sum += outs[0].as_scalar() as f64 * b as f64;
            correct += outs[1].as_scalar() as f64;
            count += b;
        }
        Ok((
            (loss_sum / count.max(1) as f64) as f32,
            (correct / count.max(1) as f64) as f32,
        ))
    }

    /// Full run: `epochs` epochs over `ds`, eval per epoch. Returns the log.
    pub fn run(&mut self, ds: &Dataset, epochs: usize, log_every: usize) -> Result<RunLog> {
        let mut log = RunLog::new(self.policy.algo.name());
        let wall0 = Instant::now();
        let b = self.rt.manifest.config.batch;
        let mut shuffle_rng = self.rng.fork(0xDA7A);
        for epoch in 0..epochs {
            let batches = ds.epoch_batches(b, &mut shuffle_rng);
            let mut ep_loss = 0.0f64;
            let mut ep_acc = 0.0f64;
            for (bi, batch) in batches.iter().enumerate() {
                let s = self.train_step(batch, epoch)?;
                ep_loss += s.loss as f64;
                ep_acc += s.acc as f64;
                if log_every > 0 && bi % log_every == 0 {
                    log.train.push(TrainRecord {
                        step: self.step,
                        epoch,
                        loss: s.loss,
                        train_acc: s.acc,
                        wall_s: wall0.elapsed().as_secs_f64(),
                    });
                }
            }
            if self.cfg.eval_every > 0 && (epoch + 1) % self.cfg.eval_every == 0 {
                let (tl, ta) = self.evaluate(ds)?;
                log.eval.push(EvalRecord {
                    step: self.step,
                    epoch,
                    test_loss: tl,
                    test_acc: ta,
                    wall_s: wall0.elapsed().as_secs_f64(),
                });
                log::info!(
                    "[{}] epoch {epoch}: train_loss={:.4} train_acc={:.4} test_acc={:.4}",
                    self.policy.algo.name(),
                    ep_loss / batches.len().max(1) as f64,
                    ep_acc / batches.len().max(1) as f64,
                    ta
                );
            }
        }
        // settle outstanding async decompositions (surfaces worker errors)
        self.drain_service()?;
        log.service = self.service_record();
        Ok(log)
    }
}
