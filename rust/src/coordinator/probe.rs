//! §4.2 error probe — the instrument behind Fig 1, Fig 2 and Table 1.
//!
//! Benchmark definition (paper): "a K-FAC algorithm with T_inv = T_updt
//! always maintains the inverse K-factors at their exact values" — the
//! probe maintains its own exact EA Grams for the probed layer and
//! recomputes dense damped inverses at every stat step, then measures:
//!
//!  (1) ‖Ã⁻¹ − A_ref⁻¹‖_F / ‖A_ref⁻¹‖_F
//!  (2) same for Γ
//!  (3) ‖s̃ − s_ref‖_F / ‖s_ref‖_F        (subspace step of the probed layer)
//!  (4) 1 − cos∠(s̃, s_ref)
//!
//! where tilde quantities come from the (approximate) algorithm under
//! test via the Trainer's capture hook.

use anyhow::Result;

use super::trainer::Trainer;
use crate::data::Dataset;
use crate::linalg::Mat;
use crate::metrics::{angle_err, dense_inv_from_rep};
use crate::util::ser::CsvWriter;

#[derive(Clone, Copy, Debug)]
pub struct ProbeRow {
    pub step: usize,
    pub m1: f32,
    pub m2: f32,
    pub m3: f32,
    pub m4: f32,
}

pub struct ErrorProbe {
    pub layer: String,
    gram_a: Option<Mat>,
    gram_g: Option<Mat>,
    inv_a_ref: Option<Mat>,
    inv_g_ref: Option<Mat>,
    lam_a_ref: f32,
    lam_g_ref: f32,
    pub rows: Vec<ProbeRow>,
}

impl ErrorProbe {
    pub fn new(layer: &str) -> ErrorProbe {
        ErrorProbe {
            layer: layer.to_string(),
            gram_a: None,
            gram_g: None,
            inv_a_ref: None,
            inv_g_ref: None,
            lam_a_ref: 0.0,
            lam_g_ref: 0.0,
            rows: Vec::new(),
        }
    }

    /// Update exact Grams + reference inverses from a stat-step capture.
    fn absorb_stats(&mut self, a_stat: &Mat, g_stat: &Mat, rho: f32, phi: f32) {
        let upd = |gram: &mut Option<Mat>, stat: &Mat| {
            let incoming = stat.syrk();
            match gram {
                None => *gram = Some(incoming),
                Some(m) => {
                    m.scale_inplace(rho);
                    m.axpy_inplace(1.0 - rho, &incoming);
                }
            }
        };
        upd(&mut self.gram_a, a_stat);
        upd(&mut self.gram_g, g_stat);
        // reference damping: λ = λ_max(exact factor) · φ (as in §6)
        let ga = self.gram_a.as_ref().unwrap();
        let gg = self.gram_g.as_ref().unwrap();
        self.lam_a_ref = (top_eig(ga) * phi).max(1e-8);
        self.lam_g_ref = (top_eig(gg) * phi).max(1e-8);
        self.inv_a_ref = Some(ga.damped_inverse(self.lam_a_ref));
        self.inv_g_ref = Some(gg.damped_inverse(self.lam_g_ref));
    }

    /// Measure the current step. Must run right after trainer.train_step.
    fn measure(&mut self, trainer: &Trainer, epoch: usize) -> Option<ProbeRow> {
        let cap = trainer.last_capture.as_ref()?;
        let phi = trainer.policy.hyper.phi_lambda(epoch);
        if cap.stat_step {
            self.absorb_stats(&cap.a_stat, &cap.g_stat, trainer.policy.hyper.rho, phi);
        }
        let (inv_a_ref, inv_g_ref) = (self.inv_a_ref.as_ref()?, self.inv_g_ref.as_ref()?);
        let layer = trainer
            .layers
            .iter()
            .find(|l| l.spec.name == self.layer)
            .expect("probe layer exists");
        if !layer.has_reps() {
            return None;
        }
        let cont = trainer.policy.hyper.spectrum_continuation;
        // approximate dense inverses as the algorithm would apply them
        let lam_a = layer.a.lambda_max() * phi;
        let lam_g = layer.g.lambda_max() * phi;
        let inv_a = dense_inv_from_rep(layer.a.rep.as_ref()?, lam_a, cont);
        let inv_g = dense_inv_from_rep(layer.g.rep.as_ref()?, lam_g, cont);
        let m1 = inv_a.rel_err(inv_a_ref);
        let m2 = inv_g.rel_err(inv_g_ref);
        // reference subspace step: Â_ref⁻¹ · grad · Γ̂_ref⁻¹ (param layout)
        let s_ref = inv_a_ref.matmul(&cap.grad).matmul(inv_g_ref);
        let m3 = cap.dir.rel_err(&s_ref);
        let m4 = angle_err(&cap.dir, &s_ref);
        Some(ProbeRow {
            step: trainer.step,
            m1,
            m2,
            m3,
            m4,
        })
    }

    /// Drive `measure_steps` training steps (after `warmup_steps` without
    /// measurement), recording one row per measured step.
    pub fn run(
        &mut self,
        trainer: &mut Trainer,
        ds: &Dataset,
        warmup_steps: usize,
        measure_steps: usize,
    ) -> Result<()> {
        let b = trainer.rt.manifest.config.batch;
        let mut rng = crate::util::rng::Rng::new(0x9B0B);
        let mut batches: Vec<crate::data::Batch> = Vec::new();
        let mut bi = 0usize;
        let mut epoch = 0usize;
        let steps_per_epoch = (ds.train_y.len() / b).max(1);
        for k in 0..(warmup_steps + measure_steps) {
            if bi >= batches.len() {
                batches = ds.epoch_batches(b, &mut rng);
                bi = 0;
            }
            trainer.train_step(&batches[bi], epoch)?;
            bi += 1;
            if trainer.step % steps_per_epoch == 0 {
                epoch += 1;
            }
            // track reference state during warmup too (it's an EA)
            if k < warmup_steps {
                if let Some(cap) = trainer.last_capture.as_ref() {
                    if cap.stat_step {
                        let phi = trainer.policy.hyper.phi_lambda(epoch);
                        let (a, g) = (cap.a_stat.clone(), cap.g_stat.clone());
                        self.absorb_stats(&a, &g, trainer.policy.hyper.rho, phi);
                    }
                }
            } else if let Some(row) = self.measure(trainer, epoch) {
                self.rows.push(row);
            }
        }
        Ok(())
    }

    /// Mean of each metric over the recorded window (Table 1 columns 1–4).
    pub fn averages(&self) -> [f32; 4] {
        let n = self.rows.len().max(1) as f32;
        let mut acc = [0.0f32; 4];
        for r in &self.rows {
            acc[0] += r.m1;
            acc[1] += r.m2;
            acc[2] += r.m3;
            acc[3] += r.m4;
        }
        acc.map(|x| x / n)
    }

    pub fn to_csv(&self) -> String {
        let mut w = CsvWriter::new(&["step", "m1_inv_a", "m2_inv_g", "m3_step", "m4_angle"]);
        for r in &self.rows {
            w.row_display(&[&r.step, &r.m1, &r.m2, &r.m3, &r.m4]);
        }
        w.to_string()
    }
}

/// Power-iteration estimate of the top eigenvalue (reference damping).
fn top_eig(m: &Mat) -> f32 {
    let n = m.rows;
    let mut v = vec![1.0f32; n];
    let mut lam = 0.0f32;
    for _ in 0..20 {
        let w = m.matvec(&v);
        lam = w.iter().map(|x| x * x).sum::<f32>().sqrt();
        if lam < 1e-30 {
            return 0.0;
        }
        for (vi, wi) in v.iter_mut().zip(&w) {
            *vi = wi / lam;
        }
    }
    lam
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn top_eig_matches_eigh() {
        let mut rng = Rng::new(110);
        let m = Mat::psd_with_decay(20, 0.6, &mut rng);
        let want = m.eigh().d[0];
        let got = top_eig(&m);
        assert!((got - want).abs() < 1e-2 * want, "{got} vs {want}");
    }

    #[test]
    fn probe_averages_math() {
        let mut p = ErrorProbe::new("fc0");
        p.rows.push(ProbeRow {
            step: 1,
            m1: 1.0,
            m2: 2.0,
            m3: 3.0,
            m4: 4.0,
        });
        p.rows.push(ProbeRow {
            step: 2,
            m1: 3.0,
            m2: 2.0,
            m3: 1.0,
            m4: 0.0,
        });
        assert_eq!(p.averages(), [2.0, 2.0, 2.0, 2.0]);
        assert!(p.to_csv().contains("m4_angle"));
    }
}
