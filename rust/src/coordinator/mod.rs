//! L3 coordinator: the training orchestrator.
//!
//! `trainer` drives the full loop (fwd/bwd artifact → stat updates →
//! scheduled decomposition updates → preconditioned step → apply) under
//! any of the seven optimizers; `probe` instruments a run with the §4.2
//! error metrics against the exact-inverse benchmark (Fig 1/2, Table 1).

pub mod probe;
pub mod trainer;

pub use trainer::{Trainer, TrainerCfg, TrainerState};
