//! bnkfac — leader entrypoint.
//!
//! Subcommands:
//!   info           inspect an artifact directory
//!   train          train with any optimizer, log curves to CSV
//!   error-study    §4.2 probe: per-step error metrics vs exact benchmark
//!   serve          multi-tenant session server: --jobs <file> runs a
//!                  scripted timeline, --listen <addr> serves the
//!                  line-delimited JSON socket protocol (DESIGN.md §12)
//!   client         speak the socket protocol to a live server
//!   loadgen        deterministic soak driver: run a scenario file of
//!                  scripted tenant archetypes against a live server
//!                  and grade the run into BENCH_soak.json
//!                  (DESIGN.md §15)
//!
//! All experiment harnesses (Fig 1/2, Tables 1/2, scaling) live in
//! `cargo bench` targets; see README.

use anyhow::{anyhow, bail, ensure, Context, Result};

use bnkfac::coordinator::probe::ErrorProbe;
use bnkfac::coordinator::{Trainer, TrainerCfg};
use bnkfac::data::{Dataset, DatasetCfg};
use bnkfac::metrics::ServerRecord;
use bnkfac::obs::Journal;
use bnkfac::optim::{Algo, Hyper};
use bnkfac::precond::PrecondCfg;
use bnkfac::runtime::Runtime;
use bnkfac::server::{frontend, proto, ServerCfg};
use bnkfac::util::cli::Args;
use bnkfac::util::ser::Json;

fn main() -> Result<()> {
    let args = Args::parse();
    match args.subcommand.as_deref() {
        Some("info") | None => cmd_info(&args),
        Some("train") => cmd_train(&args),
        Some("error-study") => cmd_error_study(&args),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some(other) => {
            bail!("unknown subcommand '{other}' (info|train|error-study|serve|client|loadgen)")
        }
    }
}

/// `--kernel {auto,scalar,blocked}` (on `train` and `serve`) selects the
/// process-wide dense-kernel backend (DESIGN.md §16). The backends are
/// bit-identical by construction, so the flag trades speed only, never
/// results; `auto` (the default) resolves to `blocked`. The resolved
/// name is surfaced in the server summary and the wire `stats` reply.
fn kernel_from(args: &Args) -> Result<()> {
    let sel = args.get_or("kernel", "auto");
    let b = bnkfac::linalg::KernelBackend::parse(sel).map_err(|e| anyhow!(e))?;
    bnkfac::linalg::kernel::set_backend(b);
    Ok(())
}

/// `--batch-factors {auto,off,N}` (on `train` and `serve`) selects the
/// process-wide factor-batching group cap (DESIGN.md §17). Like the
/// kernel backend, batched and solo drains are bit-identical by
/// construction, so the knob trades dispatch overhead only, never
/// results; `auto` (the default) resolves to a group cap of
/// [`bnkfac::precond::batch::AUTO_GROUP`]. Counters and the resolved
/// cap ride the server summary and the wire `stats` reply.
fn batch_from(args: &Args) -> Result<()> {
    let sel = args.get_or("batch-factors", "auto");
    let m = bnkfac::precond::BatchMode::parse(sel).map_err(|e| anyhow!(e))?;
    bnkfac::precond::batch::set_mode(m);
    Ok(())
}

/// Read a shared auth token from a file (DESIGN.md §12.6): surrounding
/// whitespace/newline stripped, empty tokens refused. One helper for
/// both `serve` and `client` so their token parsing cannot drift.
fn read_token_file(path: &str) -> Result<String> {
    let tok = std::fs::read_to_string(path)
        .with_context(|| format!("reading auth token file {path}"))?
        .trim()
        .to_string();
    ensure!(!tok.is_empty(), "auth token file {path} is empty");
    Ok(tok)
}

/// Export a run's event journal as JSONL (`serve --trace-out`). The
/// `journal_summary` tail carries the run's final latency percentiles
/// (p50/p90/p99 for `wire_ms`/`round_ms`/`op_ms`) pulled from the
/// final record, so a trace is self-contained for latency triage
/// (`ci/check_trace.py` asserts their presence).
fn write_trace(path: &str, journal: &Journal, rec: &ServerRecord) -> Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let wire = rec
        .frontend
        .as_ref()
        .map(|f| f.wire_ms.clone())
        .unwrap_or_default();
    let mut op = bnkfac::obs::Hist::new();
    for s in &rec.sessions {
        if let Some(svc) = &s.service {
            for (_, h) in &svc.op_ms {
                op.merge(h);
            }
        }
    }
    let mut extra = Vec::new();
    let mut fields: Vec<(String, f64)> = Vec::new();
    for (name, h) in [("wire_ms", &wire), ("round_ms", &rec.round_ms), ("op_ms", &op)] {
        fields.push((format!("{name}_p50"), h.p50_ms()));
        fields.push((format!("{name}_p90"), h.p90_ms()));
        fields.push((format!("{name}_p99"), h.p99_ms()));
    }
    for (k, v) in &fields {
        extra.push((k.as_str(), Json::Num(*v)));
    }
    std::fs::write(path, journal.export_jsonl_with(extra))?;
    println!("wrote trace {path}");
    Ok(())
}

/// Export a run's rolling time-series as JSONL (`serve --series-out`,
/// DESIGN.md §15.1).
fn write_series(path: &str, series: &bnkfac::obs::SeriesStore) -> Result<()> {
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, series.export_jsonl())?;
    println!("wrote series {path}");
    Ok(())
}

fn write_record(rec: &ServerRecord, out: Option<String>) -> Result<()> {
    println!("--- session server ---\n{}", rec.summary());
    if let Some(path) = out {
        if let Some(dir) = std::path::Path::new(&path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&path, rec.to_json().to_string_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

/// Multi-tenant session server. Two frontends over the same command
/// core (`server::driver::ServerCore`):
///
/// * `--jobs <file>` — scripted timeline (`examples/jobs_smoke.json`);
/// * `--listen <addr>` — line-delimited JSON protocol over TCP
///   (DESIGN.md §12); `--port-file <path>` writes the bound address
///   (resolving `:0`) for scripting, `--artifacts <dir>` additionally
///   enables model sessions, `--ckpt-dir <dir>` (default `results`)
///   confines wire-supplied checkpoint paths, `--idle-timeout <secs>`
///   reaps idle connections, and `--workers-min/--workers-max` bound
///   the governor's elastic worker-pool scaling (DESIGN.md §13).
///   Connection security (DESIGN.md §12.6): `--auth-token-file <path>`
///   makes a challenge–response handshake over the file's shared token
///   the mandatory first exchange on every connection;
///   `--conn-rate <req/s>` + `--conn-burst <n>` enforce a
///   per-connection token bucket (repeat offenders are disconnected);
///   `--conn-limit <n>` caps concurrent connections.
///
/// Both frontends take `--trace-out <path>`: the run records structured
/// events into the bounded journal (DESIGN.md §14.1) and exports them
/// as JSONL when serving ends. Both also take `--series-out <path>`
/// (DESIGN.md §15.1): a rolling time-series of fleet signals sampled
/// every `--series-every <k>` rounds (ring bounded by
/// `--series-cap <n>`), exported in stats replies and dumped as JSONL
/// at shutdown.
///
/// Host sessions run entirely on the host substrate — no artifacts or
/// PJRT needed.
fn cmd_serve(args: &Args) -> Result<()> {
    kernel_from(args)?;
    batch_from(args)?;
    let jobs = args.get("jobs").map(|s| s.to_string());
    let listen = args.get("listen").map(|s| s.to_string());
    let workers = args.get_usize("workers", 0);
    let out = args.get("out").map(|s| s.to_string());
    // --trace-out <path>: attach the structured event journal
    // (DESIGN.md §14.1) for the whole run and export it as JSONL
    let trace_out = args.get("trace-out").map(|s| s.to_string());
    let journal = trace_out
        .as_ref()
        .map(|_| Journal::new(bnkfac::obs::DEFAULT_CAP));
    // --series-out <path>: attach the rolling time-series store
    // (DESIGN.md §15.1), sampled every --series-every rounds, and
    // export its window as JSONL at shutdown
    let series_out = args.get("series-out").map(|s| s.to_string());
    let series_every = args.get_u64("series-every", bnkfac::obs::DEFAULT_SAMPLE_EVERY);
    let series_cap = args.get_usize("series-cap", bnkfac::obs::DEFAULT_SERIES_CAP);
    let series = series_out
        .as_ref()
        .map(|_| bnkfac::obs::SeriesStore::new(series_cap, series_every));
    match (jobs, listen) {
        (Some(_), Some(_)) => bail!("serve takes --jobs OR --listen, not both"),
        (None, None) => bail!("serve requires --jobs <file> or --listen <addr>"),
        (Some(jobs), None) => {
            // finite scripts need a runaway guard
            let max_rounds = args.get_u64("max-rounds", 1_000_000);
            args.finish().map_err(|e| anyhow!(e))?;
            let workers = (workers > 0).then_some(workers);
            let rec = bnkfac::server::driver::run_jobs_opts(
                &jobs,
                workers,
                max_rounds,
                journal.clone(),
                series.clone(),
            )?;
            if let (Some(path), Some(j)) = (&trace_out, &journal) {
                write_trace(path, j, &rec)?;
            }
            if let (Some(path), Some(s)) = (&series_out, &series) {
                write_series(path, s)?;
            }
            write_record(&rec, out)
        }
        (None, Some(addr)) => {
            // a long-lived network server is unbounded unless capped:
            // the scripted driver's round budget must not become an
            // uptime bound that kills live sessions undrained
            let max_rounds = args.get_u64("max-rounds", u64::MAX);
            let d = ServerCfg::default();
            // --workers-min/--workers-max enable elastic pool scaling
            // (DESIGN.md §13.3); equal or unset bounds keep the pool
            // fixed-size (the determinism-contract configuration)
            let cfg = ServerCfg {
                workers: if workers > 0 { workers } else { d.workers },
                max_sessions: args.get_usize("max-sessions", d.max_sessions),
                staleness: args.get_usize("staleness", d.staleness),
                workers_min: args.get_usize("workers-min", 0),
                workers_max: args.get_usize("workers-max", 0),
            };
            let rt = match args.get("artifacts") {
                Some(dir) => Some(Runtime::open(dir.to_string())?),
                None => None,
            };
            let port_file = args.get("port-file").map(|s| s.to_string());
            // wire-supplied checkpoint paths are confined under this dir
            let ckpt_dir = args.get_or("ckpt-dir", "results").to_string();
            // idle-connection reaping (seconds; 0 disables)
            let idle_s = args.get_f64("idle-timeout", 0.0);
            // connection security (DESIGN.md §12.6): shared-token
            // handshake + per-connection rate limits; all off by default
            // so localhost workflows run unchanged
            let auth_token = args.get("auth-token-file").map(read_token_file).transpose()?;
            let conn_rate = args.get_f64("conn-rate", 0.0);
            let conn_burst = args.get_f64("conn-burst", 16.0);
            let conn_limit = args.get_usize("conn-limit", 0);
            args.finish().map_err(|e| anyhow!(e))?;
            let idle = (idle_s > 0.0)
                .then(|| std::time::Duration::from_secs_f64(idle_s));
            let mut fe = frontend::bind_with(
                &addr,
                bnkfac::server::FrontendCfg {
                    idle_timeout: idle,
                    auth_token,
                    conn_rate,
                    conn_burst,
                    conn_limit,
                },
            )?;
            fe.set_ckpt_root(Some(ckpt_dir.into()));
            if let Some(j) = &journal {
                fe.set_journal(j.clone());
            }
            if let Some(s) = &series {
                fe.set_series(s.clone());
            }
            let local = fe.local_addr();
            println!("listening on {local}");
            if let Some(pf) = port_file {
                if let Some(dir) = std::path::Path::new(&pf).parent() {
                    std::fs::create_dir_all(dir)?;
                }
                std::fs::write(&pf, local.to_string())?;
            }
            let rec = fe.run(cfg, rt.as_ref(), max_rounds)?;
            if let (Some(path), Some(j)) = (&trace_out, &journal) {
                write_trace(path, j, &rec)?;
            }
            if let (Some(path), Some(s)) = (&series_out, &series) {
                write_series(path, s)?;
            }
            write_record(&rec, out)
        }
    }
}

/// Minimal protocol client for smoke tests and scripting: builds ONE
/// request from flags (or sends `--req '<json>'` verbatim), prints the
/// reply line, and exits non-zero on an error reply.
///
/// `bnkfac client --addr 127.0.0.1:4815 --op create --name a --steps 24`
///
/// Against an auth-enabled server (DESIGN.md §12.6), pass
/// `--auth-token-file <path>`: the client answers the server's
/// challenge with the keyed MAC before sending the request.
/// `--repeat <n>` sends the same request n times on ONE connection
/// (handshake once) and prints a summary instead of failing on error
/// replies — the smoke tests use it to exercise the rate limiter.
/// `--stats-watch [--interval-ms <ms>] [--frames <n>]` subscribes to
/// the server's `stats-stream` and prints one line per frame;
/// `--stats-out <path>` additionally appends each sequenced frame as
/// JSONL so soak debugging doesn't need terminal scraping.
fn cmd_client(args: &Args) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};

    let addr = args
        .get("addr")
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow!("client requires --addr <host:port>"))?;
    // --stats-watch: subscribe to the server's stats-stream and print
    // each frame; --interval-ms paces it, --frames bounds it (0 = until
    // interrupted). Mutually exclusive with building a one-shot request.
    let stats_watch = args.flag("stats-watch");
    let watch_frames = args.get_u64("frames", 0);
    let watch_interval = args.get_u64("interval-ms", 500);
    // --stats-out <path>: append each sequenced stats frame as JSONL
    let stats_out = args.get("stats-out").map(|s| s.to_string());
    ensure!(
        stats_out.is_none() || stats_watch,
        "--stats-out requires --stats-watch"
    );
    let line = if stats_watch {
        let j = Json::obj(vec![
            ("op", Json::str("stats-stream")),
            ("interval_ms", Json::Num(watch_interval as f64)),
            ("frames", Json::Num(watch_frames as f64)),
        ]);
        proto::parse_request(&j.to_string_compact())
            .map_err(|(code, msg)| anyhow!("bad stats-watch request ({code}): {msg}"))?;
        j.to_string_compact()
    } else {
        match args.get("req") {
        Some(raw) => {
            let raw = raw.to_string();
            // validate locally so typos fail before they hit the wire
            proto::parse_request(&raw)
                .map_err(|(code, msg)| anyhow!("bad --req ({code}): {msg}"))?;
            raw
        }
        None => {
            let op = args
                .get("op")
                .map(|s| s.to_string())
                .ok_or_else(|| anyhow!("client requires --op <kind> or --req '<json>'"))?;
            let mut req = vec![("op".to_string(), Json::str(&op))];
            for key in ["name", "path"] {
                if let Some(v) = args.get(key) {
                    req.push((key.to_string(), Json::str(v)));
                }
            }
            if let Some(w) = args.get("weight") {
                req.push((
                    "weight".to_string(),
                    Json::Num(w.parse::<f64>().map_err(|_| anyhow!("bad --weight"))?),
                ));
            }
            // session spec flags (create); missing fields take server
            // defaults — the lenient spec parser fills them in. The key
            // list is shared with the parser so the CLI cannot drift.
            let mut session = Vec::new();
            for key in proto::SESSION_NUM_KEYS {
                let flag = key.replace('_', "-");
                if let Some(v) = args.get(&flag) {
                    session.push((
                        key.to_string(),
                        Json::Num(
                            v.parse::<f64>().map_err(|_| anyhow!("bad --{flag}"))?,
                        ),
                    ));
                }
            }
            if let Some(a) = args.get("algo") {
                session.push(("algo".to_string(), Json::str(a)));
            }
            if let Some(s) = args.get("seed") {
                // seeds travel as strings ("0x…" hex or decimal): a JSON
                // number would round seeds above 2^53 through f64
                if s.strip_prefix("0x").is_none() {
                    s.parse::<u64>().map_err(|_| anyhow!("bad --seed"))?;
                }
                session.push(("seed".to_string(), Json::str(s)));
            }
            if op == "create" {
                req.push((
                    "session".to_string(),
                    Json::Obj(session.into_iter().collect()),
                ));
            }
            // per-session quota ceilings (governor-enforced); key list
            // shared with the parser so the CLI cannot drift
            let mut quota = Vec::new();
            for key in proto::QUOTA_NUM_KEYS {
                let flag = key.replace('_', "-");
                if let Some(v) = args.get(&flag) {
                    quota.push((
                        key.to_string(),
                        Json::Num(
                            v.parse::<f64>().map_err(|_| anyhow!("bad --{flag}"))?,
                        ),
                    ));
                }
            }
            if !quota.is_empty() {
                req.push(("quota".to_string(), Json::Obj(quota.into_iter().collect())));
            }
            let j = Json::Obj(req.into_iter().collect());
            // validate the assembled request before sending
            proto::parse_request(&j.to_string_compact())
                .map_err(|(code, msg)| anyhow!("bad request ({code}): {msg}"))?;
            j.to_string_compact()
        }
        }
    };
    let token = args.get("auth-token-file").map(read_token_file).transpose()?;
    let repeat = args.get_usize("repeat", 1).max(1);
    args.finish().map_err(|e| anyhow!(e))?;

    let stream = std::net::TcpStream::connect(&addr)
        .with_context(|| format!("connecting to {addr}"))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut out = stream;

    let read_reply = |reader: &mut BufReader<std::net::TcpStream>| -> Result<Option<String>> {
        let mut reply = String::new();
        if reader.read_line(&mut reply)? == 0 {
            return Ok(None);
        }
        Ok(Some(reply.trim_end().to_string()))
    };

    if let Some(token) = &token {
        // handshake first: the server's first line is the challenge. A
        // no-auth server sends nothing until a request arrives, so bound
        // the wait instead of hanging.
        reader
            .get_ref()
            .set_read_timeout(Some(std::time::Duration::from_secs(10)))?;
        let ch = read_reply(&mut reader)
            .context("waiting for the auth challenge (does this server require auth?)")?
            .ok_or_else(|| anyhow!("server closed before issuing an auth challenge"))?;
        let r = proto::parse_reply(&ch)?;
        let nonce = proto::challenge_nonce(&r)
            .ok_or_else(|| anyhow!("expected an auth challenge, got: {ch}"))?;
        out.write_all(proto::auth_request_line(&proto::auth_mac(token, nonce)).as_bytes())?;
        out.write_all(b"\n")?;
        out.flush()?;
        let ack = read_reply(&mut reader)?
            .ok_or_else(|| anyhow!("server closed during the auth handshake"))?;
        let r = proto::parse_reply(&ack)?;
        ensure!(r.ok, "authentication failed [{}]: {}", r.code, r.error);
        reader.get_ref().set_read_timeout(None)?;
    }

    if stats_watch {
        // open the sink before subscribing so a bad path fails fast,
        // not after frames started flowing
        let mut sink = match &stats_out {
            Some(path) => {
                if let Some(dir) = std::path::Path::new(path).parent() {
                    std::fs::create_dir_all(dir)?;
                }
                Some(
                    std::fs::OpenOptions::new()
                        .create(true)
                        .append(true)
                        .open(path)
                        .with_context(|| format!("opening --stats-out {path}"))?,
                )
            }
            None => None,
        };
        out.write_all(line.as_bytes())?;
        out.write_all(b"\n")?;
        out.flush()?;
        let mut n = 0u64;
        loop {
            let Some(reply) = read_reply(&mut reader)? else {
                break;
            };
            println!("{reply}");
            let r = proto::parse_reply(&reply)?;
            ensure!(r.ok, "server error [{}]: {}", r.code, r.error);
            if let Some(f) = &mut sink {
                f.write_all(reply.as_bytes())?;
                f.write_all(b"\n")?;
            }
            n += 1;
            // a bounded stream ends after its last frame but the server
            // keeps the connection open; stop reading ourselves
            if watch_frames > 0 && n >= watch_frames {
                break;
            }
        }
        ensure!(n > 0, "server closed before the first stats frame");
        return Ok(());
    }

    let mut sent = 0u64;
    let mut ok_count = 0u64;
    let mut err_by_code: std::collections::BTreeMap<String, u64> = Default::default();
    let mut disconnected = false;
    let mut last: Option<proto::Reply> = None;
    for _ in 0..repeat {
        if out.write_all(line.as_bytes()).is_err()
            || out.write_all(b"\n").is_err()
            || out.flush().is_err()
        {
            disconnected = true;
            break;
        }
        sent += 1;
        let reply = match read_reply(&mut reader) {
            Ok(Some(r)) => r,
            Ok(None) => {
                disconnected = true;
                break;
            }
            // a reset mid-flood is a disconnect datum, not a failure
            Err(_) if repeat > 1 => {
                disconnected = true;
                break;
            }
            Err(e) => return Err(e),
        };
        if repeat == 1 {
            println!("{reply}");
        }
        let r = proto::parse_reply(&reply)?;
        if r.ok {
            // an unexpected challenge here means the server wanted auth
            // and never saw it — surface the real refusal, not "ok"
            if proto::challenge_nonce(&r).is_some() {
                let refusal = read_reply(&mut reader)?
                    .ok_or_else(|| anyhow!("server requires auth (--auth-token-file)"))?;
                println!("{refusal}");
                let e = proto::parse_reply(&refusal)?;
                bail!(
                    "server requires auth (--auth-token-file) [{}]: {}",
                    e.code,
                    e.error
                );
            }
            ok_count += 1;
        } else {
            *err_by_code.entry(r.code.clone()).or_insert(0) += 1;
        }
        last = Some(r);
    }
    if repeat > 1 {
        let codes: Vec<String> = err_by_code
            .iter()
            .map(|(c, n)| format!("{c}={n}"))
            .collect();
        println!(
            "repeat: sent={sent} ok={ok_count} errors=[{}] disconnected={disconnected}",
            codes.join(" ")
        );
        // flood/testing mode: error replies and disconnects are data,
        // not failures
        return Ok(());
    }
    let r = last.ok_or_else(|| anyhow!("server closed the connection without replying"))?;
    ensure!(r.ok, "server error [{}]: {}", r.code, r.error);
    Ok(())
}

/// Deterministic soak driver (DESIGN.md §15): run a scenario file of
/// scripted tenant archetypes against a live `serve --listen`, merge
/// client-side latency with the server's stats/series telemetry, and
/// grade the run into `BENCH_soak.json`.
///
///   --scenario <file>        scenario JSON (examples/soak_*.json)
///   --addr <host:port>       live server address
///   --auth-token-file <f>    §12.6 shared token (if the server requires it)
///   --seed <u64>             override the scenario's seed
///   --out <file>             report path (default BENCH_soak.json)
///   --shutdown               send a final `shutdown` so the server
///                            flushes --trace-out/--series-out
///
/// Exit is nonzero on a `fail` verdict — but the report is written
/// first, so CI always has the artifact to post-mortem.
fn cmd_loadgen(args: &Args) -> Result<()> {
    let scenario_path = args
        .get("scenario")
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow!("loadgen requires --scenario <file>"))?;
    let addr = args
        .get("addr")
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow!("loadgen requires --addr <host:port>"))?;
    let token = args.get("auth-token-file").map(read_token_file).transpose()?;
    let seed_override = args.get("seed").map(|s| s.to_string());
    let out_path = args.get_or("out", "BENCH_soak.json").to_string();
    let shutdown = args.flag("shutdown");
    args.finish().map_err(|e| anyhow!(e))?;

    let text = std::fs::read_to_string(&scenario_path)
        .with_context(|| format!("reading scenario {scenario_path}"))?;
    let mut sc = bnkfac::loadgen::Scenario::parse(&text)
        .with_context(|| format!("parsing scenario {scenario_path}"))?;
    if let Some(s) = seed_override {
        sc.seed = match s.strip_prefix("0x") {
            Some(hex) => u64::from_str_radix(hex, 16).map_err(|_| anyhow!("bad --seed"))?,
            None => s.parse::<u64>().map_err(|_| anyhow!("bad --seed"))?,
        };
    }

    let (report, verdict) =
        bnkfac::loadgen::run_scenario(&sc, &addr, token.as_deref(), shutdown)?;
    if let Some(dir) = std::path::Path::new(&out_path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(&out_path, report.to_string_pretty())?;
    println!("wrote {out_path}");
    if let Some(Json::Arr(checks)) = report.get("checks") {
        for c in checks {
            let name = c.get("name").and_then(|v| v.as_str()).unwrap_or("?");
            let status = c.get("status").and_then(|v| v.as_str()).unwrap_or("?");
            let observed = c.get("observed").and_then(|v| v.as_f64()).unwrap_or(0.0);
            let limit = c.get("limit").and_then(|v| v.as_f64()).unwrap_or(0.0);
            println!("  {status:8} {name}: observed {observed:.4} vs limit {limit:.4}");
        }
    }
    println!("soak '{}' verdict: {verdict}", sc.name);
    ensure!(verdict != "fail", "soak scenario '{}' failed its SLO", sc.name);
    Ok(())
}

fn open_runtime(args: &Args) -> Result<Runtime> {
    let dir = args.get_or("artifacts", "artifacts/vgg_mini").to_string();
    Runtime::open(dir)
}

fn dataset_for(rt: &Runtime, args: &Args) -> Dataset {
    Dataset::generate(DatasetCfg {
        image: rt.manifest.config.image,
        channels: rt.manifest.config.channels,
        n_classes: rt.manifest.config.n_classes,
        n_train: args.get_usize("n-train", 4096),
        n_test: args.get_usize("n-test", 1024),
        noise: args.get_f64("data-noise", 0.35) as f32,
        label_noise: args.get_f64("label-noise", 0.0) as f32,
        seed: args.get_u64("data-seed", 1234),
        ..DatasetCfg::default()
    })
}

fn hyper_from(args: &Args) -> Result<Hyper> {
    let d = Hyper::default();
    let h = Hyper {
        rho: args.get_f64("rho", d.rho as f64) as f32,
        t_updt: args.get_usize("t-updt", d.t_updt),
        t_inv: args.get_usize("t-inv", d.t_inv),
        t_brand: args.get_usize("t-brand", d.t_brand),
        t_rsvd: args.get_usize("t-rsvd", d.t_rsvd),
        t_corct: args.get_usize("t-corct", d.t_corct),
        weight_decay: args.get_f64("wd", d.weight_decay as f64) as f32,
        clip: args.get_f64("clip", d.clip as f64) as f32,
        spectrum_continuation: !args.flag("no-spectrum-continuation"),
        brand_layer: match args.get_or("brand-layer", "fc0") {
            "all" => None,
            l => Some(l.to_string()),
        },
        linear_apply: args.flag("linear-apply"),
        lr_scale: args.get_f64("lr-scale", 1.0) as f32,
    };
    // loud cadence validation (DESIGN.md §18.5): a zero period would
    // divide by zero inside Policy::op_at, and a non-multiple of
    // --t-updt would silently fire on the lcm instead of the period
    // the flag named
    h.validate()
        .map_err(|e| anyhow::anyhow!("invalid cadence flags: {e}"))?;
    Ok(h)
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    let m = &rt.manifest;
    println!(
        "config={} image={} batch={} classes={} rank={}+{} n_pwr={}",
        m.config.name,
        m.config.image,
        m.config.batch,
        m.config.n_classes,
        m.config.rank,
        m.config.oversample,
        m.config.n_pwr
    );
    println!("params:");
    let mut total = 0usize;
    for (n, s) in &m.params {
        let c: usize = s.iter().product();
        total += c;
        println!("  {n:<20} {s:?}");
    }
    println!("  total {total} parameters");
    println!("layers:");
    for l in &m.layers {
        let brand: Vec<&str> = l
            .factors
            .iter()
            .filter(|f| f.brand)
            .map(|f| f.side.as_str())
            .collect();
        println!(
            "  {:<8} {}  d_A={} d_Γ={} k_pad={} brand-eligible={:?}",
            l.name, l.kind, l.d_a, l.d_g, l.k_pad, brand
        );
    }
    println!("{} artifacts", m.artifacts.len());
    Ok(())
}

/// `--precond-workers N [--precond-staleness S]` turns on the async
/// sharded preconditioner service; S=0 (default) is the bit-matching
/// synchronous mode, S≥1 allows decompositions to trail by S steps.
fn precond_from(args: &Args) -> Option<PrecondCfg> {
    let workers = args.get_usize("precond-workers", 0);
    let staleness = args.get_usize("precond-staleness", 0);
    if workers == 0 && staleness == 0 {
        return None;
    }
    Some(PrecondCfg {
        workers: workers.max(1),
        max_staleness: staleness,
    })
}

fn cmd_train(args: &Args) -> Result<()> {
    kernel_from(args)?;
    batch_from(args)?;
    let rt = open_runtime(args)?;
    let algo = Algo::parse(args.get_or("algo", "bkfac"))
        .ok_or_else(|| anyhow::anyhow!("bad --algo"))?;
    let epochs = args.get_usize("epochs", 5);
    let seed = args.get_u64("seed", 42);
    let out = args.get("out").map(|s| s.to_string());
    let log_every = args.get_usize("log-every", 10);
    let cfg = TrainerCfg {
        algo,
        hyper: hyper_from(args)?,
        seed,
        precond: precond_from(args),
        ..TrainerCfg::default()
    };
    let ds = dataset_for(&rt, args);
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let mut tr = Trainer::new(&rt, cfg)?;
    println!(
        "training {} for {epochs} epochs on synthetic CIFAR ({} train / {} test), {} params, kernel={} ({})",
        algo.name(),
        ds.train_y.len(),
        ds.test_y.len(),
        tr.params.n_params(),
        bnkfac::linalg::kernel::resolved_name(),
        bnkfac::linalg::kernel::simd_path()
    );
    let t0 = std::time::Instant::now();
    let log = tr.run(&ds, epochs, log_every)?;
    let wall = t0.elapsed().as_secs_f64();
    for e in &log.eval {
        println!(
            "epoch {:>3}  test_loss {:.4}  test_acc {:.4}  t={:.1}s",
            e.epoch, e.test_loss, e.test_acc, e.wall_s
        );
    }
    println!("total {wall:.1}s  t_epoch {:.2}s", wall / epochs as f64);
    println!("--- phase timers ---\n{}", tr.timers.report());
    if log.service.is_some() {
        println!("--- preconditioner service ---\n{}", log.service_summary());
    }
    if let Some(path) = out {
        std::fs::write(&path, log.to_csv())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_error_study(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let algo = Algo::parse(args.get_or("algo", "bkfac"))
        .ok_or_else(|| anyhow::anyhow!("bad --algo"))?;
    let layer = args.get_or("layer", "fc0").to_string();
    let warmup = args.get_usize("warmup", 100);
    let steps = args.get_usize("steps", 300);
    let out = args.get("out").map(|s| s.to_string());
    let cfg = TrainerCfg {
        algo,
        hyper: hyper_from(args)?,
        seed: args.get_u64("seed", 42),
        probe_layer: Some(layer.clone()),
        eval_every: 0,
        ..TrainerCfg::default()
    };
    let ds = dataset_for(&rt, args);
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let mut tr = Trainer::new(&rt, cfg)?;
    let mut probe = ErrorProbe::new(&layer);
    println!(
        "error study: {} on layer {layer}, warmup {warmup}, measuring {steps} steps",
        algo.name()
    );
    probe.run(&mut tr, &ds, warmup, steps)?;
    let avg = probe.averages();
    println!(
        "averages: inv_A {:.3e}  inv_Γ {:.3e}  step {:.3e}  angle {:.3e}",
        avg[0], avg[1], avg[2], avg[3]
    );
    if let Some(path) = out {
        std::fs::write(&path, probe.to_csv())?;
        println!("wrote {path}");
    }
    Ok(())
}
