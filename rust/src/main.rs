//! bnkfac — leader entrypoint.
//!
//! Subcommands:
//!   info           inspect an artifact directory
//!   train          train with any optimizer, log curves to CSV
//!   error-study    §4.2 probe: per-step error metrics vs exact benchmark
//!   serve          multi-tenant session server driven by a job file
//!
//! All experiment harnesses (Fig 1/2, Tables 1/2, scaling) live in
//! `cargo bench` targets; see README.

use anyhow::{anyhow, bail, Result};

use bnkfac::coordinator::probe::ErrorProbe;
use bnkfac::coordinator::{Trainer, TrainerCfg};
use bnkfac::data::{Dataset, DatasetCfg};
use bnkfac::optim::{Algo, Hyper};
use bnkfac::precond::PrecondCfg;
use bnkfac::runtime::Runtime;
use bnkfac::util::cli::Args;

fn main() -> Result<()> {
    let args = Args::parse();
    match args.subcommand.as_deref() {
        Some("info") | None => cmd_info(&args),
        Some("train") => cmd_train(&args),
        Some("error-study") => cmd_error_study(&args),
        Some("serve") => cmd_serve(&args),
        Some(other) => bail!("unknown subcommand '{other}' (info|train|error-study|serve)"),
    }
}

/// Multi-tenant session server, driven by a scripted job file (see
/// `server::driver` for the format; `examples/jobs_smoke.json` is a
/// runnable sample). Runs entirely on the host substrate — no artifacts
/// or PJRT needed.
fn cmd_serve(args: &Args) -> Result<()> {
    let jobs = args
        .get("jobs")
        .map(|s| s.to_string())
        .ok_or_else(|| anyhow!("serve requires --jobs <file>"))?;
    let workers = args.get_usize("workers", 0);
    let workers = (workers > 0).then_some(workers);
    let max_rounds = args.get_u64("max-rounds", 1_000_000);
    let out = args.get("out").map(|s| s.to_string());
    args.finish().map_err(|e| anyhow!(e))?;

    let rec = bnkfac::server::driver::run_jobs(&jobs, workers, max_rounds)?;
    println!("--- session server ---\n{}", rec.summary());
    if let Some(path) = out {
        if let Some(dir) = std::path::Path::new(&path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(&path, rec.to_json().to_string_pretty())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn open_runtime(args: &Args) -> Result<Runtime> {
    let dir = args.get_or("artifacts", "artifacts/vgg_mini").to_string();
    Runtime::open(dir)
}

fn dataset_for(rt: &Runtime, args: &Args) -> Dataset {
    Dataset::generate(DatasetCfg {
        image: rt.manifest.config.image,
        channels: rt.manifest.config.channels,
        n_classes: rt.manifest.config.n_classes,
        n_train: args.get_usize("n-train", 4096),
        n_test: args.get_usize("n-test", 1024),
        noise: args.get_f64("data-noise", 0.35) as f32,
        label_noise: args.get_f64("label-noise", 0.0) as f32,
        seed: args.get_u64("data-seed", 1234),
        ..DatasetCfg::default()
    })
}

fn hyper_from(args: &Args) -> Hyper {
    let d = Hyper::default();
    Hyper {
        rho: args.get_f64("rho", d.rho as f64) as f32,
        t_updt: args.get_usize("t-updt", d.t_updt),
        t_inv: args.get_usize("t-inv", d.t_inv),
        t_brand: args.get_usize("t-brand", d.t_brand),
        t_rsvd: args.get_usize("t-rsvd", d.t_rsvd),
        t_corct: args.get_usize("t-corct", d.t_corct),
        weight_decay: args.get_f64("wd", d.weight_decay as f64) as f32,
        clip: args.get_f64("clip", d.clip as f64) as f32,
        spectrum_continuation: !args.flag("no-spectrum-continuation"),
        brand_layer: match args.get_or("brand-layer", "fc0") {
            "all" => None,
            l => Some(l.to_string()),
        },
        linear_apply: args.flag("linear-apply"),
        lr_scale: args.get_f64("lr-scale", 1.0) as f32,
    }
}

fn cmd_info(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    let m = &rt.manifest;
    println!(
        "config={} image={} batch={} classes={} rank={}+{} n_pwr={}",
        m.config.name,
        m.config.image,
        m.config.batch,
        m.config.n_classes,
        m.config.rank,
        m.config.oversample,
        m.config.n_pwr
    );
    println!("params:");
    let mut total = 0usize;
    for (n, s) in &m.params {
        let c: usize = s.iter().product();
        total += c;
        println!("  {n:<20} {s:?}");
    }
    println!("  total {total} parameters");
    println!("layers:");
    for l in &m.layers {
        let brand: Vec<&str> = l
            .factors
            .iter()
            .filter(|f| f.brand)
            .map(|f| f.side.as_str())
            .collect();
        println!(
            "  {:<8} {}  d_A={} d_Γ={} k_pad={} brand-eligible={:?}",
            l.name, l.kind, l.d_a, l.d_g, l.k_pad, brand
        );
    }
    println!("{} artifacts", m.artifacts.len());
    Ok(())
}

/// `--precond-workers N [--precond-staleness S]` turns on the async
/// sharded preconditioner service; S=0 (default) is the bit-matching
/// synchronous mode, S≥1 allows decompositions to trail by S steps.
fn precond_from(args: &Args) -> Option<PrecondCfg> {
    let workers = args.get_usize("precond-workers", 0);
    let staleness = args.get_usize("precond-staleness", 0);
    if workers == 0 && staleness == 0 {
        return None;
    }
    Some(PrecondCfg {
        workers: workers.max(1),
        max_staleness: staleness,
    })
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let algo = Algo::parse(args.get_or("algo", "bkfac"))
        .ok_or_else(|| anyhow::anyhow!("bad --algo"))?;
    let epochs = args.get_usize("epochs", 5);
    let seed = args.get_u64("seed", 42);
    let out = args.get("out").map(|s| s.to_string());
    let log_every = args.get_usize("log-every", 10);
    let cfg = TrainerCfg {
        algo,
        hyper: hyper_from(args),
        seed,
        precond: precond_from(args),
        ..TrainerCfg::default()
    };
    let ds = dataset_for(&rt, args);
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let mut tr = Trainer::new(&rt, cfg)?;
    println!(
        "training {} for {epochs} epochs on synthetic CIFAR ({} train / {} test), {} params",
        algo.name(),
        ds.train_y.len(),
        ds.test_y.len(),
        tr.params.n_params()
    );
    let t0 = std::time::Instant::now();
    let log = tr.run(&ds, epochs, log_every)?;
    let wall = t0.elapsed().as_secs_f64();
    for e in &log.eval {
        println!(
            "epoch {:>3}  test_loss {:.4}  test_acc {:.4}  t={:.1}s",
            e.epoch, e.test_loss, e.test_acc, e.wall_s
        );
    }
    println!("total {wall:.1}s  t_epoch {:.2}s", wall / epochs as f64);
    println!("--- phase timers ---\n{}", tr.timers.report());
    if log.service.is_some() {
        println!("--- preconditioner service ---\n{}", log.service_summary());
    }
    if let Some(path) = out {
        std::fs::write(&path, log.to_csv())?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_error_study(args: &Args) -> Result<()> {
    let rt = open_runtime(args)?;
    let algo = Algo::parse(args.get_or("algo", "bkfac"))
        .ok_or_else(|| anyhow::anyhow!("bad --algo"))?;
    let layer = args.get_or("layer", "fc0").to_string();
    let warmup = args.get_usize("warmup", 100);
    let steps = args.get_usize("steps", 300);
    let out = args.get("out").map(|s| s.to_string());
    let cfg = TrainerCfg {
        algo,
        hyper: hyper_from(args),
        seed: args.get_u64("seed", 42),
        probe_layer: Some(layer.clone()),
        eval_every: 0,
        ..TrainerCfg::default()
    };
    let ds = dataset_for(&rt, args);
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let mut tr = Trainer::new(&rt, cfg)?;
    let mut probe = ErrorProbe::new(&layer);
    println!(
        "error study: {} on layer {layer}, warmup {warmup}, measuring {steps} steps",
        algo.name()
    );
    probe.run(&mut tr, &ds, warmup, steps)?;
    let avg = probe.averages();
    println!(
        "averages: inv_A {:.3e}  inv_Γ {:.3e}  step {:.3e}  angle {:.3e}",
        avg[0], avg[1], avg[2], avg[3]
    );
    if let Some(path) = out {
        std::fs::write(&path, probe.to_csv())?;
        println!("wrote {path}");
    }
    Ok(())
}
