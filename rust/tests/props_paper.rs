//! The paper's theoretical results as executable properties
//! (Propositions 3.1, 3.2, 4.1, 4.2 + the §3.3 psd argument), checked
//! over randomized EA K-factor streams with the in-repo property harness.

use bnkfac::linalg::{LowRank, Mat};
use bnkfac::util::proptest::{check, run, PropConfig};
use bnkfac::util::rng::Rng;

/// Random EA stream setup shared by the propositions.
struct Stream {
    d: usize,
    r: usize,
    n: usize,
    rho: f32,
    steps: usize,
    seed: u64,
}

impl std::fmt::Debug for Stream {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Stream(d={},r={},n={},rho={},steps={},seed={})",
            self.d, self.r, self.n, self.rho, self.steps, self.seed
        )
    }
}

fn gen_stream(rng: &mut Rng) -> Stream {
    let n = 2 + rng.next_below(4);
    let r = (3 + rng.next_below(6)).max(n);
    let d = r + n + 5 + rng.next_below(20);
    Stream {
        d,
        r,
        n,
        rho: 0.8 + 0.15 * rng.next_f32(),
        steps: 2 + rng.next_below(5),
        seed: rng.next_u64(),
    }
}

/// Evolve the exact EA factor and the pure-B process together.
fn evolve(s: &Stream) -> (Mat, LowRank) {
    let mut rng = Rng::new(s.seed);
    let a0 = Mat::gauss(s.d, s.n, 1.0, &mut rng);
    let mut m_true = a0.syrk();
    let mut b_est = LowRank::from_eigh(&m_true.eigh(), s.r.min(s.n) + 0);
    for _ in 0..s.steps {
        let a = Mat::gauss(s.d, s.n, 1.0, &mut rng);
        m_true = m_true.scale(s.rho).add(&a.syrk().scale(1.0 - s.rho));
        b_est = b_est.brand_ea_update(&a, s.rho, s.r);
    }
    (m_true, b_est)
}

/// Prop 3.1 (part 2): ‖M_k − M̃_{B,k}‖ ≥ ‖M_k − M̃_{R,k,r+n}‖ — the
/// Brand-maintained rank-(r+n) estimate can never beat the OPTIMAL
/// rank-(r+n) truncation, in Frobenius norm.
#[test]
fn prop_3_1_brand_error_bounded_below_by_optimal() {
    check("prop 3.1", gen_stream, |s| {
        let (m_true, b_est) = evolve(s);
        let err_b = b_est.to_dense().sub(&m_true).fro_norm();
        let opt = LowRank::from_eigh(&m_true.eigh(), s.r + s.n).to_dense();
        let err_opt = opt.sub(&m_true).fro_norm();
        if err_b >= err_opt - 1e-3 * (1.0 + err_opt) {
            Ok(())
        } else {
            Err(format!("brand err {err_b} < optimal {err_opt}"))
        }
    });
}

/// Prop 3.1 (part 1): the rank-r truncation 𝓑_k of the B-process is no
/// better than the optimal rank-r truncation of M_k.
#[test]
fn prop_3_1_truncated_brand_vs_optimal_rank_r() {
    check("prop 3.1 part 1", gen_stream, |s| {
        let (m_true, b_est) = evolve(s);
        let b_trunc = b_est.truncate(s.r).to_dense();
        let err_b = b_trunc.sub(&m_true).fro_norm();
        let opt = LowRank::from_eigh(&m_true.eigh(), s.r).to_dense();
        let err_opt = opt.sub(&m_true).fro_norm();
        if err_b >= err_opt - 1e-3 * (1.0 + err_opt) {
            Ok(())
        } else {
            Err(format!("B_k err {err_b} < optimal {err_opt}"))
        }
    });
}

/// Prop 3.2 structure: truncation-error matrices M̃_{B,k} − 𝓑_k are
/// symmetric PSD along the whole B-process.
#[test]
fn prop_3_2_truncation_errors_are_psd() {
    check("prop 3.2 psd", gen_stream, |s| {
        let (_, b_est) = evolve(s);
        let err = b_est.to_dense().sub(&b_est.truncate(s.r).to_dense());
        // symmetry
        let sym_err = err.sub(&err.transpose()).max_abs();
        if sym_err > 1e-3 {
            return Err(format!("not symmetric: {sym_err}"));
        }
        let ev = err.eigh();
        let min_eig = ev.d.last().copied().unwrap_or(0.0);
        if min_eig > -1e-3 * (1.0 + ev.d[0].abs()) {
            Ok(())
        } else {
            Err(format!("truncation error not PSD: min eig {min_eig}"))
        }
    });
}

/// Prop 3.2 one-step consequence: overwriting 𝓑_i with the optimal
/// rank-r truncation gives a better (or equal) error at i+1 than the
/// pure B process: ‖E^{R@i}_{i+1}‖ ≤ ‖E^{pure}_{i+1}‖.
#[test]
fn prop_3_2_overwrite_helps_next_iteration() {
    check("prop 3.2 overwrite", gen_stream, |s| {
        let (m_true, b_est) = evolve(s);
        let mut rng = Rng::new(s.seed ^ 0xFEED);
        let a_next = Mat::gauss(s.d, s.n, 1.0, &mut rng);
        let m_next = m_true.scale(s.rho).add(&a_next.syrk().scale(1.0 - s.rho));
        // pure: truncate the B estimate; overwritten: truncate M_true optimally
        let pure_next = b_est.brand_ea_update(&a_next, s.rho, s.r);
        let over_start = LowRank::from_eigh(&m_true.eigh(), s.r);
        let over_next = over_start.brand_update(&a_next.scale((1.0 - s.rho).sqrt()));
        // scale over_start inside brand: use brand_ea semantics directly
        let over_next2 = {
            let scaled = LowRank::new(
                over_start.u.clone(),
                over_start.d.iter().map(|&x| s.rho * x).collect(),
            );
            let _ = over_next;
            scaled.brand_update(&a_next.scale((1.0 - s.rho).sqrt()))
        };
        let e_pure = pure_next.to_dense().sub(&m_next).fro_norm();
        let e_over = over_next2.to_dense().sub(&m_next).fro_norm();
        if e_over <= e_pure + 1e-3 * (1.0 + e_pure) {
            Ok(())
        } else {
            Err(format!("overwrite worsened next step: {e_over} > {e_pure}"))
        }
    });
}

/// Prop 4.1/4.2: B-updates beat NO updates. Starting both from the
/// optimal rank-r truncation at k=0, after several EA arrivals the
/// B-updated estimate must have error ≤ the frozen estimate's error.
#[test]
fn prop_4_x_b_updates_beat_no_updates() {
    // statistically true for decaying spectra; use more steps to separate
    run(
        "prop 4.x",
        PropConfig {
            cases: 16,
            ..Default::default()
        },
        |rng| {
            let mut s = gen_stream(rng);
            s.steps = 6 + rng.next_below(6);
            s
        },
        |s| {
            let mut rng = Rng::new(s.seed);
            let a0 = Mat::gauss(s.d, s.n, 1.0, &mut rng);
            let mut m_true = a0.syrk();
            let init = LowRank::from_eigh(&m_true.eigh(), s.r);
            let frozen = init.clone();
            let mut b_est = init;
            for _ in 0..s.steps {
                let a = Mat::gauss(s.d, s.n, 1.0, &mut rng);
                m_true = m_true.scale(s.rho).add(&a.syrk().scale(1.0 - s.rho));
                b_est = b_est.brand_ea_update(&a, s.rho, s.r);
            }
            let e_b = b_est.to_dense().sub(&m_true).fro_norm();
            let e_frozen = frozen.to_dense().sub(&m_true).fro_norm();
            if e_b <= e_frozen + 1e-3 {
                Ok(())
            } else {
                Err(format!("B-update worse than frozen: {e_b} > {e_frozen}"))
            }
        },
    );
}

/// Prop 4.2 bound: per-arrival truncation error of the B-process is
/// bounded by ‖M_j M_jᵀ‖_F (the (1−ρ)-scaled incoming term, eq. 18).
#[test]
fn prop_4_2_per_step_error_bound() {
    check("prop 4.2 bound", gen_stream, |s| {
        let mut rng = Rng::new(s.seed);
        let a0 = Mat::gauss(s.d, s.n, 1.0, &mut rng);
        let m0 = a0.syrk();
        let mut b_est = LowRank::from_eigh(&m0.eigh(), s.r);
        for _ in 0..s.steps {
            let a = Mat::gauss(s.d, s.n, 1.0, &mut rng);
            let before = b_est.truncate(s.r);
            let after = before.brand_ea_update(&a, s.rho, s.r);
            // E_j = (M̃_j − 𝓑_j)/(1−ρ) where the truncation error is taken
            // at the next truncation; bound: ‖E_j‖_F ≤ ‖M_jM_jᵀ‖_F
            let trunc_err = after
                .to_dense()
                .sub(&after.truncate(s.r).to_dense())
                .fro_norm()
                / (1.0 - s.rho);
            let bound = a.syrk().fro_norm();
            if trunc_err <= bound * (1.0 + 1e-3) + 1e-4 {
                b_est = after;
            } else {
                return Err(format!("‖E_j‖={trunc_err} > bound {bound}"));
            }
        }
        Ok(())
    });
}

/// §3.3 "Why use M̃_B,k, not 𝓑_k": ‖M_k − 𝓑_k‖ ≥ ‖M_k − M̃_{B,k}‖.
#[test]
fn sec_3_3_full_rep_beats_truncated_rep() {
    check("§3.3 ordering", gen_stream, |s| {
        let (m_true, b_est) = evolve(s);
        let e_full = b_est.to_dense().sub(&m_true).fro_norm();
        let e_trunc = b_est.truncate(s.r).to_dense().sub(&m_true).fro_norm();
        if e_trunc >= e_full - 1e-3 * (1.0 + e_full) {
            Ok(())
        } else {
            Err(format!("truncated beat full: {e_trunc} < {e_full}"))
        }
    });
}

/// Brand exactness (§2.3): one un-truncated Brand update reproduces the
/// dense EA update to float precision, for any stream dims.
#[test]
fn brand_update_is_exact_property() {
    check("brand exactness", gen_stream, |s| {
        let mut rng = Rng::new(s.seed);
        let g = Mat::gauss(s.d, s.r, 1.0, &mut rng);
        let rep = LowRank::from_eigh(&g.syrk().eigh(), s.r);
        let a = Mat::gauss(s.d, s.n, 1.0, &mut rng);
        let upd = rep.brand_ea_update(&a, s.rho, s.r);
        let want = rep.to_dense().scale(s.rho).add(&a.syrk().scale(1.0 - s.rho));
        let rel = upd.to_dense().rel_err(&want);
        if rel < 5e-4 {
            Ok(())
        } else {
            Err(format!("brand not exact: rel err {rel}"))
        }
    });
}
