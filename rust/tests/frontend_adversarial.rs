//! Adversarial wire-protocol suite (DESIGN.md §12.6).
//!
//! Drives raw localhost sockets with hostile input — random byte soup,
//! truncated and oversized frames, unauthenticated first commands,
//! replayed handshake transcripts, and request floods — and pins down
//! the frontend's survival claims:
//!
//! * the serving thread never panics and the server keeps serving
//!   compliant connections afterwards;
//! * every reply to hostile input carries a code from the CLOSED error
//!   set (`proto::ERROR_CODES`);
//! * a replayed challenge response is rejected (nonces are
//!   per-connection);
//! * a flooding connection walks the rate-limit strike ladder to
//!   disconnection while a concurrent compliant session's trajectory
//!   bit-matches a solo run.
//!
//! These are exactly the claims that die without hostile tests — the
//! handshake and rate limiter were co-designed with this suite.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::time::Duration;

use bnkfac::metrics::ServerRecord;
use bnkfac::server::{frontend, proto, FrontendCfg, ServerCfg};
use bnkfac::util::rng::Rng;
use bnkfac::util::ser::Json;

const TOKEN: &str = "adversarial-suite-shared-token";

fn tmp_dir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bnkfac_adv_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn server_cfg() -> ServerCfg {
    ServerCfg {
        workers: 2,
        max_sessions: 4,
        staleness: 1,
        ..ServerCfg::default()
    }
}

fn start_server(
    fcfg: FrontendCfg,
) -> (SocketAddr, std::thread::JoinHandle<anyhow::Result<ServerRecord>>) {
    let mut fe = frontend::bind_with("127.0.0.1:0", fcfg).expect("bind");
    fe.set_ckpt_root(Some(tmp_dir()));
    let addr = fe.local_addr();
    let h = std::thread::spawn(move || fe.run(server_cfg(), None, 100_000_000));
    (addr, h)
}

/// Raw test connection: unlike `bnkfac client` it sends whatever bytes
/// it is told to and survives server-initiated closes.
struct Conn {
    reader: BufReader<TcpStream>,
    out: TcpStream,
}

impl Conn {
    fn open(addr: SocketAddr) -> Conn {
        let stream = TcpStream::connect(addr).expect("connect");
        // bound every read so a silent server fails the test instead of
        // hanging it
        stream
            .set_read_timeout(Some(Duration::from_secs(20)))
            .unwrap();
        Conn {
            reader: BufReader::new(stream.try_clone().unwrap()),
            out: stream,
        }
    }

    /// Send raw bytes followed by `\n`; false when the peer is gone.
    fn send(&mut self, payload: &[u8]) -> bool {
        self.out.write_all(payload).is_ok()
            && self.out.write_all(b"\n").is_ok()
            && self.out.flush().is_ok()
    }

    /// Read one reply line; `None` on EOF / reset / timeout.
    fn read_line(&mut self) -> Option<String> {
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) | Err(_) => None,
            Ok(_) => Some(line.trim_end().to_string()),
        }
    }

    fn read_reply(&mut self) -> Option<proto::Reply> {
        let line = self.read_line()?;
        Some(proto::parse_reply(&line).expect("server replies parse as wire replies"))
    }

    /// Send a request line and expect a reply.
    fn req(&mut self, line: &str) -> Option<proto::Reply> {
        if !self.send(line.as_bytes()) {
            return None;
        }
        self.read_reply()
    }

    fn ok(&mut self, line: &str) -> Json {
        let r = self.req(line).expect("server replied");
        assert!(r.ok, "request {line} failed: [{}] {}", r.code, r.error);
        r.data
    }

    /// Complete the §12.6 handshake (challenge must be the first line).
    fn authenticate(&mut self, token: &str) -> u64 {
        let ch = self.read_reply().expect("challenge");
        let nonce = proto::challenge_nonce(&ch).expect("first line is a challenge");
        let r = self
            .req(&proto::auth_request_line(&proto::auth_mac(token, nonce)))
            .expect("auth reply");
        assert!(r.ok, "handshake failed: [{}] {}", r.code, r.error);
        assert_eq!(r.data.get("auth").and_then(|v| v.as_str()), Some("ok"));
        nonce
    }
}

fn wait_status(c: &mut Conn, name: &str, want: &str, pace: Duration) {
    for _ in 0..4000 {
        let data = c.ok(r#"{"op": "stats"}"#);
        let done = data
            .get("sessions")
            .and_then(|v| v.as_arr())
            .map(|ss| {
                ss.iter().any(|s| {
                    s.get("name").and_then(|v| v.as_str()) == Some(name)
                        && s.get("status").and_then(|v| v.as_str()) == Some(want)
                })
            })
            .unwrap_or(false);
        if done {
            return;
        }
        std::thread::sleep(pace);
    }
    panic!("session '{name}' never reached status {want}");
}

// NB: one physical line — the protocol is line-delimited.
fn session_spec_json() -> &'static str {
    r#"{"factors": 2, "dim": 36, "rank": 5, "n_stat": 3, "grad_cols": 4, "t_updt": 2, "algo": "b-kfac", "seed": "0x5eed", "steps": 24, "rho": 0.95, "lambda": 0.1}"#
}

// ------------------------------------------------------- hostile bytes

/// Arbitrary single-frame payloads — byte soup, JSON-ish soup, and
/// truncations of a valid request — never panic the server, always get
/// a closed-set error code (or a legitimate ok), and never poison the
/// connection state for subsequent well-formed requests.
#[test]
fn garbage_frames_get_closed_set_replies_and_server_survives() {
    let (addr, server) = start_server(FrontendCfg::default());
    let mut rng = Rng::new(0xADBE);
    const JSONISH: &[u8] = br#"{}[]",:0123456789.eE+-truefalsn\u"opnamecreate "#;
    let valid = format!(
        r#"{{"op": "create", "name": "x", "session": {}}}"#,
        session_spec_json()
    );

    let mut replies = 0u64;
    for case in 0..48 {
        let payload: Vec<u8> = match case % 3 {
            // raw bytes (newlines stripped so one send = one frame;
            // NULs allowed — the frame reader must cope)
            0 => {
                let n = 1 + rng.next_below(200);
                (0..n)
                    .map(|_| rng.next_u64() as u8)
                    .filter(|&b| b != b'\n' && b != b'\r')
                    .collect()
            }
            // JSON-shaped soup that gets deep into the parser
            1 => {
                let n = 1 + rng.next_below(200);
                (0..n).map(|_| JSONISH[rng.next_below(JSONISH.len())]).collect()
            }
            // a valid request truncated at a random byte
            _ => {
                let cut = 1 + rng.next_below(valid.len() - 1);
                valid.as_bytes()[..cut].to_vec()
            }
        };
        // blank frames (valid UTF-8, all whitespace) are ignored by
        // design and draw no reply — match the server's trim semantics
        if std::str::from_utf8(&payload)
            .map(|s| s.trim().is_empty())
            .unwrap_or(false)
        {
            continue;
        }
        // fresh connection per case: a hostile frame may legally close it
        let mut c = Conn::open(addr);
        assert!(c.send(&payload), "case {case}: send failed");
        let reply = c
            .read_reply()
            .unwrap_or_else(|| panic!("case {case}: no reply to {payload:?}"));
        replies += 1;
        if !reply.ok {
            assert!(
                proto::ERROR_CODES.contains(&reply.code.as_str()),
                "case {case}: code '{}' outside the closed set",
                reply.code
            );
        }
    }
    assert!(replies > 30, "suite degenerated: only {replies} replies");

    // the serving thread survived all of it
    let mut c = Conn::open(addr);
    c.ok(r#"{"op": "stats"}"#);
    c.ok(r#"{"op": "shutdown"}"#);
    let rec = server.join().unwrap().expect("server run");
    let f = rec.frontend.expect("frontend counters");
    assert!(f.rejected > 0 && f.rejected <= f.requests);
}

/// A peer that sends a partial line and vanishes (truncated frame, no
/// terminator) must not wedge the server or leak its reader thread into
/// the command path.
#[test]
fn truncated_frame_then_hangup_is_harmless() {
    let (addr, server) = start_server(FrontendCfg::default());
    for _ in 0..8 {
        let mut c = Conn::open(addr);
        // no trailing newline, then an abrupt close
        c.out.write_all(br#"{"op": "create", "name": "#).unwrap();
        c.out.flush().unwrap();
        drop(c);
    }
    let mut c = Conn::open(addr);
    c.ok(r#"{"op": "stats"}"#);
    c.ok(r#"{"op": "shutdown"}"#);
    server.join().unwrap().unwrap();
}

/// An oversized frame is refused with `oversized`, the connection is
/// closed, and the force-close is attributed to the connection id in
/// the final record's drop events.
#[test]
fn oversized_frame_drop_is_attributed_to_its_conn_id() {
    let (addr, server) = start_server(FrontendCfg::default());
    let mut c = Conn::open(addr);
    let huge = vec![b'z'; proto::MAX_LINE + 64];
    assert!(c.send(&huge));
    let r = c.read_reply().expect("oversized reply");
    assert!(!r.ok);
    assert_eq!(r.code, proto::E_OVERSIZED);
    assert!(c.req(r#"{"op": "stats"}"#).is_none(), "connection survived");

    let mut c2 = Conn::open(addr);
    c2.ok(r#"{"op": "stats"}"#);
    c2.ok(r#"{"op": "shutdown"}"#);
    let rec = server.join().unwrap().unwrap();
    let f = rec.frontend.expect("frontend counters");
    assert!(f.conn_dropped >= 1);
    assert!(
        f.drop_events
            .iter()
            .any(|(conn, reason)| *conn == 1 && reason == "oversized"),
        "drop not attributed: {:?}",
        f.drop_events
    );
}

// --------------------------------------------------------- handshake

/// The §12.6 handshake: a correct MAC authenticates; skipping the
/// handshake is `auth_required`; a wrong MAC — including a REPLAYED
/// response captured from another connection — is `auth_failed`; all
/// three close the connection before any command is parsed.
#[test]
fn handshake_rejects_unauthenticated_wrong_mac_and_replay() {
    let (addr, server) = start_server(FrontendCfg {
        auth_token: Some(TOKEN.into()),
        ..FrontendCfg::default()
    });

    // compliant connection: challenge → MAC → serve normally
    let mut a = Conn::open(addr);
    let nonce_a = a.authenticate(TOKEN);
    a.ok(r#"{"op": "stats"}"#);

    // replay: a fresh connection gets a fresh nonce, so connection A's
    // captured response proves nothing
    let mut b = Conn::open(addr);
    let ch = b.read_reply().expect("challenge");
    let nonce_b = proto::challenge_nonce(&ch).expect("challenge");
    assert_ne!(nonce_a, nonce_b, "nonces must be per-connection");
    let replayed = proto::auth_mac(TOKEN, nonce_a);
    let r = b.req(&proto::auth_request_line(&replayed)).expect("reply");
    assert!(!r.ok);
    assert_eq!(r.code, proto::E_AUTH_FAILED);
    assert!(b.req(r#"{"op": "stats"}"#).is_none(), "replayed conn lived");

    // skipping the handshake: the first line is a command, not auth
    let mut c = Conn::open(addr);
    let ch = c.read_reply().expect("challenge");
    assert!(proto::challenge_nonce(&ch).is_some());
    let r = c.req(r#"{"op": "shutdown"}"#).expect("refusal");
    assert!(!r.ok);
    assert_eq!(r.code, proto::E_AUTH_REQUIRED);
    assert!(c.req(r#"{"op": "stats"}"#).is_none(), "unauth conn lived");

    // wrong MAC outright
    let mut d = Conn::open(addr);
    let _ = d.read_reply().expect("challenge");
    let r = d
        .req(&proto::auth_request_line("0xdeadbeefdeadbeefdeadbeefdeadbeef"))
        .expect("reply");
    assert!(!r.ok);
    assert_eq!(r.code, proto::E_AUTH_FAILED);

    // the authenticated connection is still fully functional — and the
    // unauthenticated `shutdown` above was NOT applied
    a.ok(r#"{"op": "stats"}"#);
    a.ok(r#"{"op": "shutdown"}"#);
    let rec = server.join().unwrap().unwrap();
    let f = rec.frontend.expect("frontend counters");
    assert!(f.auth_failures >= 3, "auth_failures={}", f.auth_failures);
    assert!(
        f.drop_events.iter().any(|(_, r)| r == "auth_required"),
        "{:?}",
        f.drop_events
    );
    assert!(
        f.drop_events.iter().any(|(_, r)| r == "auth_failed"),
        "{:?}",
        f.drop_events
    );
}

/// With no token configured the handshake machinery must be completely
/// inert: no challenge line, first reply is the command's own.
#[test]
fn no_token_means_no_challenge() {
    let (addr, server) = start_server(FrontendCfg::default());
    let mut c = Conn::open(addr);
    let r = c.req(r#"{"op": "stats"}"#).expect("reply");
    assert!(r.ok, "[{}] {}", r.code, r.error);
    assert!(
        proto::challenge_nonce(&r).is_none(),
        "no-auth server issued a challenge"
    );
    c.ok(r#"{"op": "shutdown"}"#);
    server.join().unwrap().unwrap();
}

// ------------------------------------------------------- rate limiting

/// Acceptance criterion: a flooding connection trips `rate_limited` and
/// is disconnected on the strike ladder, while a concurrent compliant
/// connection's session finishes with a checkpoint that bit-matches a
/// solo (flood-free, rate-limit-free) run.
#[test]
fn flood_is_limited_and_compliant_session_bitmatches_solo() {
    let spec = format!(
        r#"{{"op": "create", "name": "a", "weight": 2, "session": {}}}"#,
        session_spec_json()
    );

    // solo reference: default (unlimited) frontend
    let solo_ck = tmp_dir().join("adv_solo.json");
    {
        let (addr, server) = start_server(FrontendCfg::default());
        let mut c = Conn::open(addr);
        c.ok(&spec);
        wait_status(&mut c, "a", "Done", Duration::from_millis(5));
        c.ok(r#"{"op": "checkpoint", "name": "a", "path": "adv_solo.json"}"#);
        c.ok(r#"{"op": "shutdown"}"#);
        server.join().unwrap().unwrap();
    }

    // contended run: rate-limited frontend, one flooder + one compliant
    let conc_ck = tmp_dir().join("adv_conc.json");
    let (addr, server) = start_server(FrontendCfg {
        conn_rate: 20.0,
        conn_burst: 50.0,
        ..FrontendCfg::default()
    });
    let mut c = Conn::open(addr);
    c.ok(&spec);

    // flood from a second connection: full-speed stats requests
    let flood = std::thread::spawn(move || {
        let mut f = Conn::open(addr);
        let mut limited = 0u64;
        let mut disconnected = false;
        for _ in 0..100_000 {
            let Some(r) = f.req(r#"{"op": "stats"}"#) else {
                disconnected = true;
                break;
            };
            if !r.ok {
                assert_eq!(r.code, proto::E_RATE_LIMITED, "unexpected: {}", r.code);
                limited += 1;
            }
        }
        (limited, disconnected)
    });
    let (limited, disconnected) = flood.join().unwrap();
    assert!(limited >= 1, "flood never tripped the rate limiter");
    assert!(disconnected, "flooder was never disconnected");

    // the compliant connection paces itself under 20 req/s and finishes
    wait_status(&mut c, "a", "Done", Duration::from_millis(100));
    c.ok(r#"{"op": "checkpoint", "name": "a", "path": "adv_conc.json"}"#);
    let stats = c.ok(r#"{"op": "stats"}"#);
    let f = stats.get("frontend").expect("frontend in stats");
    assert!(
        f.get("rate_limited").and_then(|v| v.as_usize()).unwrap() >= 1,
        "rate_limited counter missing from stats"
    );
    c.ok(r#"{"op": "shutdown"}"#);
    let rec = server.join().unwrap().unwrap();
    let fr = rec.frontend.expect("frontend counters");
    assert!(fr.rate_limited >= 1);
    assert!(fr.conn_dropped >= 1);
    // the drop is attributed to the flooder (conn 2; the compliant
    // connection was conn 1), so assertions do not race on ordering
    assert!(
        fr.drop_events
            .iter()
            .any(|(conn, reason)| *conn == 2 && reason == "rate_limited"),
        "{:?}",
        fr.drop_events
    );

    // determinism: the flood must not have perturbed the trajectory
    let solo = Json::parse(&std::fs::read_to_string(&solo_ck).unwrap()).unwrap();
    let conc = Json::parse(&std::fs::read_to_string(&conc_ck).unwrap()).unwrap();
    assert_eq!(solo.get("cfg"), conc.get("cfg"), "session cfg diverged");
    assert_eq!(
        solo.get("state"),
        conc.get("state"),
        "flooded run diverged bit-wise from the solo run"
    );
    let _ = std::fs::remove_file(solo_ck);
    let _ = std::fs::remove_file(conc_ck);
}

/// A rate-limited request is refused AND discarded: exactly one reply
/// per request (no desync) and the over-rate command is never applied.
#[test]
fn rate_limited_request_is_not_applied() {
    // refill is 1 token per 20s: even a badly stalled CI runner cannot
    // re-admit the second request
    let (addr, server) = start_server(FrontendCfg {
        conn_rate: 0.05,
        conn_burst: 1.0,
        ..FrontendCfg::default()
    });
    let mut c = Conn::open(addr);
    // burst of 1: the first create is admitted…
    let r = c
        .req(&format!(
            r#"{{"op": "create", "name": "kept", "session": {}}}"#,
            session_spec_json()
        ))
        .expect("reply 1");
    assert!(r.ok, "[{}] {}", r.code, r.error);
    // …the immediate second one is refused with rate_limited — and must
    // NOT create the session
    let r = c
        .req(&format!(
            r#"{{"op": "create", "name": "refused", "session": {}}}"#,
            session_spec_json()
        ))
        .expect("reply 2");
    assert!(!r.ok);
    assert_eq!(r.code, proto::E_RATE_LIMITED);

    // fresh connections get fresh buckets: one request each
    let mut c2 = Conn::open(addr);
    let data = c2.ok(r#"{"op": "stats"}"#);
    let names: Vec<String> = data
        .get("sessions")
        .and_then(|v| v.as_arr())
        .unwrap()
        .iter()
        .map(|s| s.get("name").and_then(|v| v.as_str()).unwrap().to_string())
        .collect();
    assert_eq!(names, vec!["kept".to_string()], "refused create was applied");
    let mut c3 = Conn::open(addr);
    c3.ok(r#"{"op": "shutdown"}"#);
    server.join().unwrap().unwrap();
}

/// The connection cap refuses excess connections with `at_capacity`
/// before a reader thread exists, and frees the slot when a connection
/// closes.
#[test]
fn conn_limit_refuses_then_recovers() {
    let (addr, server) = start_server(FrontendCfg {
        conn_limit: 1,
        ..FrontendCfg::default()
    });
    let mut a = Conn::open(addr);
    a.ok(r#"{"op": "stats"}"#);

    let mut b = Conn::open(addr);
    let r = b.read_reply().expect("refusal line");
    assert!(!r.ok);
    assert_eq!(r.code, proto::E_AT_CAPACITY);
    assert!(b.req(r#"{"op": "stats"}"#).is_none(), "refused conn lived");

    drop(a); // frees the slot once the reader thread sees EOF
    let mut c = None;
    for _ in 0..200 {
        let mut probe = Conn::open(addr);
        if let Some(r) = probe.req(r#"{"op": "stats"}"#) {
            if r.ok {
                c = Some(probe);
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    let mut c = c.expect("slot never freed after close");
    c.ok(r#"{"op": "shutdown"}"#);
    let rec = server.join().unwrap().unwrap();
    let f = rec.frontend.expect("frontend counters");
    assert!(
        f.drop_events.iter().any(|(_, r)| r == "conn_limit"),
        "{:?}",
        f.drop_events
    );
}

// ------------------------------------------------------- stats-stream

/// `stats-stream` round-trip: a bounded subscription delivers exactly
/// `frames` sequenced snapshot frames, each a well-formed stats reply
/// (wire-latency histogram included), and the connection remains usable
/// for ordinary requests afterwards.
#[test]
fn stats_stream_delivers_sequenced_frames_then_connection_survives() {
    let (addr, server) = start_server(FrontendCfg::default());
    let mut c = Conn::open(addr);
    c.ok(&format!(
        r#"{{"op": "create", "name": "a", "session": {}}}"#,
        session_spec_json()
    ));
    assert!(c.send(br#"{"op": "stats-stream", "interval_ms": 10, "frames": 3}"#));
    for want_seq in 0..3u64 {
        let line = c.read_line().expect("stream frame");
        let j = Json::parse(&line).expect("frame parses");
        assert_eq!(j.get("ok").and_then(|v| v.as_bool()), Some(true), "{line}");
        assert_eq!(
            j.get("seq").and_then(|v| v.as_usize()),
            Some(want_seq as usize),
            "frames must be sequenced: {line}"
        );
        let data = j.get("data").expect("frame data");
        assert!(data.get("sessions").is_some(), "{line}");
        assert!(data.get("uptime_ms").is_some(), "{line}");
        // the frontend counters ride every stats frame, wire histogram
        // included
        assert!(
            data.get("frontend").and_then(|f| f.get("wire_ms")).is_some(),
            "{line}"
        );
    }
    // the stream ended; the same connection serves ordinary requests
    c.ok(r#"{"op": "stats"}"#);
    c.ok(r#"{"op": "shutdown"}"#);
    let rec = server.join().unwrap().unwrap();
    let f = rec.frontend.expect("frontend counters");
    assert_eq!(
        f.by_kind.iter().find(|(k, _)| k == "stats-stream").map(|(_, n)| *n),
        Some(1),
        "{:?}",
        f.by_kind
    );
    assert!(f.wire_ms.count() > 0, "wire latency histogram empty");
}

/// A garbage subscriber — unbounded stream, never reads a byte — must
/// not wedge the serving thread: concurrent connections keep being
/// served and `shutdown` still brings the server down cleanly.
#[test]
fn unread_unbounded_stream_cannot_wedge_serving_thread() {
    let (addr, server) = start_server(FrontendCfg::default());
    // subscriber asks for an unbounded fast stream and then never reads:
    // its socket buffer fills and its CONNECTION thread blocks, but the
    // serving thread only ever posts replies to an unbounded channel
    let mut zombie = Conn::open(addr);
    assert!(zombie.send(br#"{"op": "stats-stream", "interval_ms": 10, "frames": 0}"#));

    let mut c = Conn::open(addr);
    c.ok(&format!(
        r#"{{"op": "create", "name": "a", "session": {}}}"#,
        session_spec_json()
    ));
    wait_status(&mut c, "a", "Done", Duration::from_millis(5));
    c.ok(r#"{"op": "shutdown"}"#);
    let rec = server.join().unwrap().unwrap();
    assert!(rec.frontend.is_some());
    drop(zombie);
}

/// Hostile input against an AUTH-ENABLED server: garbage, oversized and
/// truncated first lines must all die in the handshake with a closed
/// set code — never reaching command parsing — and the server survives.
#[test]
fn garbage_against_auth_server_dies_in_handshake() {
    let (addr, server) = start_server(FrontendCfg {
        auth_token: Some(TOKEN.into()),
        ..FrontendCfg::default()
    });
    let mut rng = Rng::new(0xFACE);
    for case in 0..24 {
        let mut c = Conn::open(addr);
        let ch = c.read_reply().expect("challenge");
        assert!(proto::challenge_nonce(&ch).is_some());
        let payload: Vec<u8> = if case % 4 == 0 {
            vec![b'q'; proto::MAX_LINE + 8] // oversized first frame
        } else {
            let n = 1 + rng.next_below(120);
            (0..n)
                .map(|_| rng.next_u64() as u8)
                .filter(|&b| b != b'\n' && b != b'\r')
                .collect()
        };
        // no blank-line skip here: the handshake answers EVERY first
        // frame, including empty ones, with a refusal
        assert!(c.send(&payload));
        let r = c.read_reply().expect("handshake refusal");
        assert!(!r.ok);
        assert!(
            [proto::E_AUTH_REQUIRED, proto::E_AUTH_FAILED, proto::E_OVERSIZED]
                .contains(&r.code.as_str()),
            "case {case}: code '{}' not a handshake refusal",
            r.code
        );
        assert!(c.req(r#"{"op": "stats"}"#).is_none(), "case {case}: conn lived");
    }
    let mut c = Conn::open(addr);
    c.authenticate(TOKEN);
    c.ok(r#"{"op": "shutdown"}"#);
    server.join().unwrap().unwrap();
}
