//! Bit-parity suite for the dense-kernel backends (DESIGN.md §16).
//!
//! The blocked backend's contract is not "close to" the scalar
//! reference — it is BIT-IDENTICAL on every op and every shape,
//! because each output element is accumulated in the same ascending-k
//! order with a single accumulator regardless of tiling or lane
//! width. These tests enforce that contract where it is most likely
//! to crack: empty and degenerate dims (0×n, 1×n), sizes straddling
//! the 8-lane width and the 128-wide cache tiles, IEEE special values
//! (the NaN-propagation semantics the old zero-skip swallowed), and —
//! end to end — a full multi-session server run whose checkpoint must
//! serialize to identical bytes under either backend.
//!
//! The backend selector is process-global, so scalar-vs-blocked runs
//! of the *routed* paths happen sequentially inside a single #[test];
//! concurrent tests seeing either backend is benign precisely because
//! the backends are bit-identical.

use bnkfac::linalg::kernel::{self, blocked::Blocked, scalar::Scalar, Backend, Kernels};
use bnkfac::linalg::Mat;
use bnkfac::optim::Algo;
use bnkfac::server::{HostSessionCfg, ServerCfg, SessionManager};
use bnkfac::util::proptest::check;
use bnkfac::util::rng::Rng;

fn fill32(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_gauss_f32()).collect()
}

fn fill64(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.next_gauss()).collect()
}

fn bits32(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

fn bits64(x: &[f64]) -> Vec<u64> {
    x.iter().map(|v| v.to_bits()).collect()
}

/// Dimension generator biased toward the boundaries that break tiled
/// code: 0, 1, one-below/at/one-above the lane width, and a spread
/// that crosses the 64/128 tile edges.
fn dim(rng: &mut Rng) -> usize {
    match rng.next_below(8) {
        0 => 0,
        1 => 1,
        2 => 7,
        3 => 8,
        4 => 9,
        5 => 63 + rng.next_below(4),  // straddle MC = 64
        6 => 127 + rng.next_below(4), // straddle KC = NC = 128
        _ => 2 + rng.next_below(48),
    }
}

struct Shape {
    r: usize,
    n: usize,
    k: usize,
    seed: u64,
}

impl std::fmt::Debug for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Shape(r={},n={},k={},seed={})",
            self.r, self.n, self.k, self.seed
        )
    }
}

fn gen_shape(rng: &mut Rng) -> Shape {
    Shape {
        r: dim(rng),
        n: dim(rng),
        k: dim(rng),
        seed: rng.next_u64(),
    }
}

/// Run one op through both backends from identical inputs and demand
/// identical output bits.
fn expect_same(op: &str, s: &Shape, got_scalar: &[u32], got_blocked: &[u32]) -> Result<(), String> {
    if got_scalar == got_blocked {
        return Ok(());
    }
    let idx = got_scalar
        .iter()
        .zip(got_blocked)
        .position(|(a, b)| a != b)
        .unwrap();
    Err(format!(
        "{op} diverges at flat index {idx} for {s:?}: scalar bits {:#010x} vs blocked {:#010x}",
        got_scalar[idx], got_blocked[idx]
    ))
}

#[test]
fn matrix_kernels_bit_identical_across_shapes() {
    check("kernel parity: matrix ops", gen_shape, |s| {
        let mut rng = Rng::new(s.seed);
        let (r, n, k) = (s.r, s.n, s.k);

        // gemm: c (r×n) += a (r×k) · b (k×n), accumulating into a
        // random (not zero) C so the += semantics are exercised.
        let a = fill32(&mut rng, r * k);
        let b = fill32(&mut rng, k * n);
        let c0 = fill32(&mut rng, r * n);
        let mut cs = c0.clone();
        let mut cb = c0.clone();
        Scalar.gemm(r, n, k, &a, &b, &mut cs);
        Blocked.gemm(r, n, k, &a, &b, &mut cb);
        expect_same("gemm", s, &bits32(&cs), &bits32(&cb))?;

        // gemm_tn: c (r×n) += aᵀ·b for a: k×r, b: k×n.
        let at = fill32(&mut rng, k * r);
        let mut cs = c0.clone();
        let mut cb = c0.clone();
        Scalar.gemm_tn(r, n, k, &at, &b, &mut cs);
        Blocked.gemm_tn(r, n, k, &at, &b, &mut cb);
        expect_same("gemm_tn", s, &bits32(&cs), &bits32(&cb))?;

        // gemm_nt: c (r×n) = a (r×k) · bᵀ for b: n×k.
        let bt = fill32(&mut rng, n * k);
        let mut cs = c0.clone();
        let mut cb = c0.clone();
        Scalar.gemm_nt(r, n, k, &a, &bt, &mut cs);
        Blocked.gemm_nt(r, n, k, &a, &bt, &mut cb);
        expect_same("gemm_nt", s, &bits32(&cs), &bits32(&cb))?;

        // syrk over a random row panel [r0, r0+pr) of A·Aᵀ, A: r×k.
        // Untouched (j < i) panel entries keep their init in both runs.
        let r0 = if r == 0 { 0 } else { rng.next_below(r) };
        let pr = r - r0;
        let p0 = fill32(&mut rng, pr * r);
        let mut ps = p0.clone();
        let mut pb = p0;
        Scalar.syrk(r0, pr, r, k, &a, &mut ps);
        Blocked.syrk(r0, pr, r, k, &a, &mut pb);
        expect_same("syrk", s, &bits32(&ps), &bits32(&pb))?;

        // gemv: y (r) = a (r×n) · x (n).
        let av = fill32(&mut rng, r * n);
        let x = fill32(&mut rng, n);
        let mut ys = vec![0.5f32; r];
        let mut yb = vec![0.5f32; r];
        Scalar.gemv(r, n, &av, &x, &mut ys);
        Blocked.gemv(r, n, &av, &x, &mut yb);
        expect_same("gemv", s, &bits32(&ys), &bits32(&yb))?;
        Ok(())
    });
}

#[test]
fn vector_kernels_bit_identical_across_lengths() {
    check("kernel parity: vector ops", gen_shape, |s| {
        let mut rng = Rng::new(s.seed);
        let len = s.k;
        let alpha = rng.next_gauss_f32();

        let x = fill32(&mut rng, len);
        let y = fill32(&mut rng, len);
        let ds = Scalar.dot(&x, &y);
        let db = Blocked.dot(&x, &y);
        if ds.to_bits() != db.to_bits() {
            return Err(format!("dot diverges for {s:?}: {ds:?} vs {db:?}"));
        }
        let mut ys = y.clone();
        let mut yb = y.clone();
        Scalar.axpy(alpha, &x, &mut ys);
        Blocked.axpy(alpha, &x, &mut yb);
        expect_same("axpy", s, &bits32(&ys), &bits32(&yb))?;

        let xd = fill64(&mut rng, len);
        let yd = fill64(&mut rng, len);
        if Scalar.ddot(&xd, &yd).to_bits() != Blocked.ddot(&xd, &yd).to_bits() {
            return Err(format!("ddot diverges for {s:?}"));
        }
        let init = rng.next_gauss();
        if Scalar.ddot_sub(init, &xd, &yd).to_bits() != Blocked.ddot_sub(init, &xd, &yd).to_bits()
        {
            return Err(format!("ddot_sub diverges for {s:?}"));
        }
        let mut ds = yd.clone();
        let mut db = yd.clone();
        Scalar.daxpy(init, &xd, &mut ds);
        Blocked.daxpy(init, &xd, &mut db);
        if bits64(&ds) != bits64(&db) {
            return Err(format!("daxpy diverges for {s:?}"));
        }
        Ok(())
    });
}

/// IEEE special values must propagate identically — including the NaN
/// *payload bits*, which depend on operand order. This is the case the
/// historical zero-skip silently got wrong (0·inf skipped instead of
/// producing NaN).
#[test]
fn special_values_propagate_identically() {
    let specials = [
        0.0f32,
        -0.0,
        1.0,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::NAN,
        f32::MIN_POSITIVE / 2.0, // subnormal
        -3.5,
    ];
    // 11×11 operands cycle through all pairings of specials, with the
    // dims chosen to leave a 3-wide tail past the 8-lane width.
    let (r, n, k) = (11usize, 11usize, 11usize);
    let cyc = |len: usize, off: usize| -> Vec<f32> {
        (0..len).map(|i| specials[(i + off) % specials.len()]).collect()
    };
    let a = cyc(r * k, 0);
    let b = cyc(k * n, 3);
    let c0 = cyc(r * n, 5);

    let mut cs = c0.clone();
    let mut cb = c0.clone();
    Scalar.gemm(r, n, k, &a, &b, &mut cs);
    Blocked.gemm(r, n, k, &a, &b, &mut cb);
    assert_eq!(bits32(&cs), bits32(&cb), "gemm special-value bits");
    assert!(cs.iter().any(|v| v.is_nan()), "0·inf must surface as NaN");

    let mut cs = c0.clone();
    let mut cb = c0.clone();
    Scalar.gemm_tn(r, n, k, &a, &b, &mut cs);
    Blocked.gemm_tn(r, n, k, &a, &b, &mut cb);
    assert_eq!(bits32(&cs), bits32(&cb), "gemm_tn special-value bits");

    let mut cs = c0.clone();
    let mut cb = c0;
    Scalar.gemm_nt(r, n, k, &a, &b, &mut cs);
    Blocked.gemm_nt(r, n, k, &a, &b, &mut cb);
    assert_eq!(bits32(&cs), bits32(&cb), "gemm_nt special-value bits");

    let x = cyc(n, 1);
    let mut ys = vec![0.0f32; r];
    let mut yb = vec![0.0f32; r];
    Scalar.gemv(r, n, &cyc(r * n, 2), &x, &mut ys);
    Blocked.gemv(r, n, &cyc(r * n, 2), &x, &mut yb);
    assert_eq!(bits32(&ys), bits32(&yb), "gemv special-value bits");
}

/// The Mat-level entry points (threaded dispatch, tile mirroring,
/// counter recording) must also be backend-invariant — sizes here are
/// past PAR_FLOPS_MIN so the row-parallel split is exercised too.
#[test]
fn mat_ops_bit_identical_across_backends() {
    let mut rng = Rng::new(0xC0FFEE);
    // 161·117·123 ≈ 2.3M FLOPs > PAR_FLOPS_MIN (2²¹), so matmul and
    // matmul_t take the threaded row-split; dims are deliberately not
    // multiples of the 8-lane width or the 64/128 tiles.
    let a = Mat::gauss(161, 117, 1.0, &mut rng);
    let b = Mat::gauss(117, 123, 1.0, &mut rng);
    let at = Mat::gauss(117, 131, 1.0, &mut rng);
    let bt = Mat::gauss(123, 117, 1.0, &mut rng);
    let x: Vec<f32> = (0..117).map(|_| rng.next_gauss_f32()).collect();

    let calls_before: u64 = kernel::snapshot().iter().map(|c| c.calls).sum();
    let run = |backend: Backend| {
        kernel::set_backend(backend);
        let mm = a.matmul(&b);
        let tm = at.t_matmul(&b);
        let mt = a.matmul_t(&bt);
        let sy = a.syrk();
        let mv = a.matvec(&x[..117]);
        (
            bits32(&mm.data),
            bits32(&tm.data),
            bits32(&mt.data),
            bits32(&sy.data),
            bits32(&mv),
        )
    };
    let s = run(Backend::Scalar);
    let bl = run(Backend::Blocked);
    kernel::set_backend(Backend::Auto);
    assert_eq!(s.0, bl.0, "matmul bits differ across backends");
    assert_eq!(s.1, bl.1, "t_matmul bits differ across backends");
    assert_eq!(s.2, bl.2, "matmul_t bits differ across backends");
    assert_eq!(s.3, bl.3, "syrk bits differ across backends");
    assert_eq!(s.4, bl.4, "matvec bits differ across backends");

    // Counters are process-global and shared with concurrent tests, so
    // only monotonicity is checkable here — the ops above must have
    // registered at least once each (2 backends × 5 ops).
    let calls_after: u64 = kernel::snapshot().iter().map(|c| c.calls).sum();
    assert!(
        calls_after >= calls_before + 10,
        "kernel counters did not advance: {calls_before} -> {calls_after}"
    );
}

fn scfg(seed: u64, algo: Algo, steps: u64) -> HostSessionCfg {
    HostSessionCfg {
        factors: 2,
        dim: 36,
        rank: 5,
        n_stat: 3,
        grad_cols: 4,
        t_updt: 2,
        algo,
        seed,
        steps,
        rho: 0.95,
        lambda: 0.1,
        policy: None,
    }
}

/// End-to-end determinism: a multi-session server run (EA stat
/// updates, Brand chains, eigendecompositions, preconditioned applies
/// — every routed path at once) checkpointed under the scalar backend
/// must serialize to the EXACT bytes of the same run under the
/// blocked backend.
#[test]
fn checkpoints_byte_identical_across_backends() {
    let run = |backend: Backend| -> String {
        kernel::set_backend(backend);
        let mut mgr = SessionManager::new(ServerCfg {
            workers: 2,
            max_sessions: 4,
            staleness: 1,
            ..ServerCfg::default()
        });
        let a = mgr
            .create_host("a", 1, scfg(11, Algo::BKfac, 24), None)
            .unwrap();
        let b = mgr
            .create_host("b", 1, scfg(22, Algo::BKfacC, 24), None)
            .unwrap();
        mgr.run_to_completion(1_000_000).unwrap();
        let ja = mgr.checkpoint(a).unwrap().to_string_pretty();
        let jb = mgr.checkpoint(b).unwrap().to_string_pretty();
        format!("{ja}\n{jb}")
    };
    let scalar = run(Backend::Scalar);
    let blocked = run(Backend::Blocked);
    kernel::set_backend(Backend::Auto);
    assert!(
        scalar.len() > 200,
        "checkpoint suspiciously small — workload did not run"
    );
    assert_eq!(
        scalar, blocked,
        "server checkpoints differ between scalar and blocked backends"
    );
}
