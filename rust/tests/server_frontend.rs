//! Network-frontend integration tests (DESIGN.md §12): protocol
//! round-trips, framing rejection, a live localhost server driven
//! through create → checkpoint → restore → drop → shutdown, the
//! socket-vs-job-file bit-match, SENG checkpoint/resume bit-identity,
//! and (artifact-gated) model-session restore through the command core.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;
use std::sync::OnceLock;

use bnkfac::coordinator::TrainerCfg;
use bnkfac::data::{Dataset, DatasetCfg};
use bnkfac::optim::seng::SengState;
use bnkfac::optim::Algo;
use bnkfac::runtime::Runtime;
use bnkfac::server::proto::{self, Command, DataSpec, ModelSpec, QuotaSpec};
use bnkfac::server::{ckpt, driver, frontend, HostSessionCfg, ServerCfg, SessionManager, Workload};
use bnkfac::util::rng::Rng;
use bnkfac::util::ser::Json;

fn tmp_dir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("bnkfac_frontend_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn tmp_path(name: &str) -> PathBuf {
    tmp_dir().join(name)
}

// ------------------------------------------------------------- protocol

fn roundtrip(cmd: Command) {
    let j = proto::command_to_json(&cmd);
    let back = proto::parse_request(&j.to_string_compact()).expect("round-trip parse");
    assert_eq!(
        proto::command_to_json(&back),
        j,
        "command {:?} did not survive the wire",
        cmd.kind()
    );
}

#[test]
fn proto_roundtrip_every_command() {
    roundtrip(Command::Create {
        name: "a".into(),
        weight: 3,
        session: HostSessionCfg {
            algo: Algo::BKfacC,
            seed: u64::MAX - 7,
            steps: 17,
            ..HostSessionCfg::default()
        },
        quota: None,
    });
    roundtrip(Command::Create {
        name: "q".into(),
        weight: 1,
        session: HostSessionCfg::default(),
        quota: Some(QuotaSpec {
            max_op_rate: 2.5,
            max_mem_mb: 64.0,
        }),
    });
    roundtrip(Command::CreateModel {
        name: "m".into(),
        weight: 2,
        model: ModelSpec {
            algo: Algo::Seng,
            seed: 0xDEAD_BEEF,
            steps: 12,
        },
        dataset: DataSpec {
            n_train: 128,
            n_test: 32,
            noise: 0.5,
            label_noise: 0.1,
            seed: 7,
        },
        quota: Some(QuotaSpec {
            max_op_rate: 8.0,
            max_mem_mb: 0.0,
        }),
    });
    roundtrip(Command::Pause { name: "a".into() });
    roundtrip(Command::Resume { name: "a".into() });
    roundtrip(Command::Checkpoint {
        name: "a".into(),
        path: "results/a.json".into(),
    });
    roundtrip(Command::Restore {
        name: "b".into(),
        path: "results/a.json".into(),
        dataset: None,
    });
    roundtrip(Command::Restore {
        name: "b".into(),
        path: "results/a.json".into(),
        dataset: Some(DataSpec::default()),
    });
    roundtrip(Command::Drop { name: "a".into() });
    roundtrip(Command::Stats);
    roundtrip(Command::Shutdown);
}

#[test]
fn proto_rejects_malformed_and_unknown() {
    let (code, _) = proto::parse_request("{oops").unwrap_err();
    assert_eq!(code, proto::E_MALFORMED);
    let (code, _) = proto::parse_request(r#"{"op": "explode"}"#).unwrap_err();
    assert_eq!(code, proto::E_BAD_REQUEST);
    let (code, msg) = proto::parse_request(r#"{"op": "checkpoint", "name": "a"}"#).unwrap_err();
    assert_eq!(code, proto::E_BAD_REQUEST);
    assert!(msg.contains("path"), "{msg}");
}

// ------------------------------------------------------ live socket e2e

struct Client {
    reader: BufReader<TcpStream>,
    out: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        Client {
            reader: BufReader::new(stream.try_clone().unwrap()),
            out: stream,
        }
    }

    fn send_raw(&mut self, line: &str) -> Option<proto::Reply> {
        self.out.write_all(line.as_bytes()).ok()?;
        self.out.write_all(b"\n").ok()?;
        self.out.flush().ok()?;
        let mut reply = String::new();
        if self.reader.read_line(&mut reply).ok()? == 0 {
            return None;
        }
        Some(proto::parse_reply(reply.trim_end()).expect("reply parses"))
    }

    fn req(&mut self, line: &str) -> proto::Reply {
        self.send_raw(line).expect("server replied")
    }

    fn ok(&mut self, line: &str) -> Json {
        let r = self.req(line);
        assert!(r.ok, "request {line} failed: [{}] {}", r.code, r.error);
        r.data
    }
}

// NB: ONE physical line — this spec is spliced into wire requests, and
// the protocol is line-delimited; an embedded newline would shear the
// request into malformed frames.
fn session_spec_json() -> &'static str {
    r#"{"factors": 2, "dim": 36, "rank": 5, "n_stat": 3, "grad_cols": 4, "t_updt": 2, "algo": "b-kfac", "seed": "0x2a", "steps": 24, "rho": 0.95, "lambda": 0.1}"#
}

/// Bind a frontend with wire checkpoint paths rooted in the test tmp
/// dir and serve it on a background thread.
fn start_server(
    cfg: ServerCfg,
) -> (SocketAddr, std::thread::JoinHandle<anyhow::Result<bnkfac::metrics::ServerRecord>>) {
    let mut fe = frontend::bind("127.0.0.1:0").expect("bind");
    fe.set_ckpt_root(Some(tmp_dir()));
    let addr = fe.local_addr();
    let h = std::thread::spawn(move || fe.run(cfg, None, 100_000_000));
    (addr, h)
}

fn wait_status(c: &mut Client, name: &str, want: &str) {
    for _ in 0..4000 {
        let data = c.ok(r#"{"op": "stats"}"#);
        let done = data
            .get("sessions")
            .and_then(|v| v.as_arr())
            .map(|ss| {
                ss.iter().any(|s| {
                    s.get("name").and_then(|v| v.as_str()) == Some(name)
                        && s.get("status").and_then(|v| v.as_str()) == Some(want)
                })
            })
            .unwrap_or(false);
        if done {
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    panic!("session '{name}' never reached status {want}");
}

/// The acceptance path: a live server on a localhost socket serving
/// create → checkpoint → restore → drop for an external client, with
/// structured errors for bad requests and counters in the final record.
#[test]
fn socket_client_drives_full_lifecycle() {
    let (addr, server) = start_server(ServerCfg {
        workers: 2,
        max_sessions: 4,
        staleness: 1,
        ..ServerCfg::default()
    });
    let mut c = Client::connect(addr);

    // request validation: structured error replies, connection survives
    let r = c.req(r#"{"op": "pause", "name": "ghost"}"#);
    assert!(!r.ok);
    assert_eq!(r.code, proto::E_NOT_FOUND);
    let r = c.req("{not json");
    assert!(!r.ok);
    assert_eq!(r.code, proto::E_MALFORMED);

    // create / pause / resume
    let data = c.ok(&format!(
        r#"{{"op": "create", "name": "a", "weight": 2, "session": {}}}"#,
        session_spec_json()
    ));
    assert!(data.get("id").and_then(|v| v.as_usize()).unwrap() >= 1);
    let dup = c.req(&format!(
        r#"{{"op": "create", "name": "a", "session": {}}}"#,
        session_spec_json()
    ));
    assert!(!dup.ok, "duplicate name admitted");
    assert_eq!(dup.code, proto::E_BAD_REQUEST);
    c.ok(r#"{"op": "pause", "name": "a"}"#);
    c.ok(r#"{"op": "resume", "name": "a"}"#);
    wait_status(&mut c, "a", "Done");

    // wire paths are confined under the server's checkpoint root:
    // absolute and parent-escaping paths are rejected up front
    let r = c.req(r#"{"op": "checkpoint", "name": "a", "path": "../escape.json"}"#);
    assert!(!r.ok);
    assert_eq!(r.code, proto::E_BAD_REQUEST);
    let r = c.req(r#"{"op": "checkpoint", "name": "a", "path": "/etc/nope.json"}"#);
    assert!(!r.ok);
    assert_eq!(r.code, proto::E_BAD_REQUEST);

    // checkpoint → restore → both checkpoints bit-match (paths on the
    // wire are relative; files land under the server's root = tmp_dir)
    let ck1 = tmp_path("socket_a.json");
    let data = c.ok(r#"{"op": "checkpoint", "name": "a", "path": "socket_a.json"}"#);
    assert_eq!(data.get("step").and_then(|v| v.as_usize()), Some(24));
    let data = c.ok(r#"{"op": "restore", "name": "a2", "path": "socket_a.json"}"#);
    assert_eq!(data.get("kind").and_then(|v| v.as_str()), Some("host"));
    assert_eq!(data.get("step").and_then(|v| v.as_usize()), Some(24));
    let ck2 = tmp_path("socket_a2.json");
    c.ok(r#"{"op": "checkpoint", "name": "a2", "path": "socket_a2.json"}"#);
    let j1 = Json::parse(&std::fs::read_to_string(&ck1).unwrap()).unwrap();
    let j2 = Json::parse(&std::fs::read_to_string(&ck2).unwrap()).unwrap();
    assert_eq!(
        j1.get("state"),
        j2.get("state"),
        "restored session state diverged from its checkpoint"
    );

    // drop both; stats shows no sessions and carries frontend counters
    c.ok(r#"{"op": "drop", "name": "a"}"#);
    c.ok(r#"{"op": "drop", "name": "a2"}"#);
    let data = c.ok(r#"{"op": "stats"}"#);
    assert_eq!(
        data.get("sessions").and_then(|v| v.as_arr()).map(|a| a.len()),
        Some(0)
    );
    let fc = data.get("frontend").expect("stats carries frontend counters");
    assert!(fc.get("requests").and_then(|v| v.as_usize()).unwrap() > 5);

    c.ok(r#"{"op": "shutdown"}"#);
    let rec = server.join().unwrap().expect("server run");
    let f = rec.frontend.expect("record carries frontend counters");
    assert_eq!(f.connections, 1);
    // ghost pause + malformed line + duplicate create + 2 bad paths
    assert!(f.rejected >= 5, "rejected={}", f.rejected);
    assert!(f.rejected <= f.requests, "rejected > requests");
    assert!(
        f.by_kind.iter().any(|(k, n)| k == "checkpoint" && *n >= 2),
        "{:?}",
        f.by_kind
    );

    let _ = std::fs::remove_file(ck1);
    let _ = std::fs::remove_file(ck2);
}

/// Determinism across frontends: the same session config driven over the
/// socket and via a scripted job file must produce bit-identical
/// checkpoint state (cfg + full session state).
#[test]
fn socket_run_bitmatches_job_file_run() {
    // job-file-driven reference
    let job_ck = tmp_path("job_a.json");
    let job_file = tmp_path("jobs.json");
    std::fs::write(
        &job_file,
        format!(
            r#"{{"server": {{"workers": 2, "max_sessions": 4, "staleness": 1}},
                "jobs": [
                  {{"at": 0, "action": "create", "name": "a", "weight": 2,
                    "session": {}}},
                  {{"at": 2000, "action": "checkpoint", "name": "a",
                    "path": "{}"}}
                ]}}"#,
            session_spec_json(),
            job_ck.display()
        ),
    )
    .unwrap();
    let rec = driver::run_jobs(job_file.to_str().unwrap(), None, 1_000_000).unwrap();
    assert_eq!(rec.total_steps, 24);

    // socket-driven run of the identical session
    let (addr, server) = start_server(ServerCfg {
        workers: 2,
        max_sessions: 4,
        staleness: 1,
        ..ServerCfg::default()
    });
    let mut c = Client::connect(addr);
    c.ok(&format!(
        r#"{{"op": "create", "name": "a", "weight": 2, "session": {}}}"#,
        session_spec_json()
    ));
    wait_status(&mut c, "a", "Done");
    // relative on the wire; resolves under the server's root (tmp_dir)
    let sock_ck = tmp_path("sock_a.json");
    c.ok(r#"{"op": "checkpoint", "name": "a", "path": "sock_a.json"}"#);
    c.ok(r#"{"op": "shutdown"}"#);
    server.join().unwrap().unwrap();

    let jj = Json::parse(&std::fs::read_to_string(&job_ck).unwrap()).unwrap();
    let sj = Json::parse(&std::fs::read_to_string(&sock_ck).unwrap()).unwrap();
    assert_eq!(jj.get("cfg"), sj.get("cfg"), "session cfg diverged");
    assert_eq!(
        jj.get("state"),
        sj.get("state"),
        "socket-driven trajectory diverged from the job-file-driven one"
    );

    for p in [job_ck, job_file, sock_ck] {
        let _ = std::fs::remove_file(p);
    }
}

/// Idle-connection reaping (ROADMAP frontend hardening): a connection
/// that sends nothing for `--idle-timeout` is answered `idle_timeout`,
/// closed, and counted; active connections and the server itself are
/// unaffected.
#[test]
fn idle_connections_are_reaped_and_counted() {
    let mut fe = frontend::bind_cfg(
        "127.0.0.1:0",
        Some(std::time::Duration::from_millis(60)),
    )
    .expect("bind");
    fe.set_ckpt_root(Some(tmp_dir()));
    let addr = fe.local_addr();
    let server =
        std::thread::spawn(move || fe.run(ServerCfg::default(), None, 100_000_000));

    // a promptly-busy connection is fine
    let mut live = Client::connect(addr);
    live.ok(r#"{"op": "stats"}"#);

    // an idle one gets reaped: courtesy error line, then EOF
    let mut idle = Client::connect(addr);
    std::thread::sleep(std::time::Duration::from_millis(400));
    let mut line = String::new();
    let n = idle.reader.read_line(&mut line).unwrap_or(0);
    if n > 0 {
        let r = proto::parse_reply(line.trim_end()).expect("reply parses");
        assert!(!r.ok);
        assert_eq!(r.code, proto::E_IDLE_TIMEOUT);
    }
    assert!(
        idle.send_raw(r#"{"op": "stats"}"#).is_none(),
        "reaped connection still serviced"
    );

    // fresh connections keep working; the final record counts the reap
    let mut c2 = Client::connect(addr);
    c2.ok(r#"{"op": "stats"}"#);
    c2.ok(r#"{"op": "shutdown"}"#);
    let rec = server.join().unwrap().expect("server run");
    let f = rec.frontend.expect("frontend counters");
    assert!(f.idle_reaped >= 1, "idle_reaped={}", f.idle_reaped);
}

/// Quota ceilings ride the wire: an over-quota session created through
/// the socket is evicted by the governor, the record carries the
/// eviction counter and the elastic worker-count fields, and a
/// compliant session is untouched — the CI governor smoke in
/// `.github/workflows/ci.yml` drives this same path via `bnkfac client`.
#[test]
fn socket_created_over_quota_session_is_evicted() {
    let (addr, server) = start_server(ServerCfg {
        workers: 2,
        max_sessions: 4,
        staleness: 1,
        ..ServerCfg::default()
    });
    let mut c = Client::connect(addr);
    c.ok(&format!(
        r#"{{"op": "create", "name": "ok", "session": {}}}"#,
        session_spec_json()
    ));
    // NB: one physical line — the protocol is line-delimited
    c.ok(
        r#"{"op": "create", "name": "flood", "session": {"steps": 4000, "t_updt": 2}, "quota": {"max_op_rate": 0.05}}"#,
    );
    wait_status(&mut c, "flood", "Evicted");
    wait_status(&mut c, "ok", "Done");
    let data = c.ok(r#"{"op": "stats"}"#);
    assert_eq!(data.get("evictions").and_then(|v| v.as_usize()), Some(1));
    assert!(data.get("workers_now").and_then(|v| v.as_usize()).unwrap() >= 1);
    let flood = data
        .get("sessions")
        .and_then(|v| v.as_arr())
        .unwrap()
        .iter()
        .find(|s| s.get("name").and_then(|v| v.as_str()) == Some("flood"))
        .unwrap()
        .clone();
    assert_eq!(
        flood.get("evict_reason").and_then(|v| v.as_str()),
        Some("op_rate")
    );
    c.ok(r#"{"op": "shutdown"}"#);
    server.join().unwrap().unwrap();
}

/// An oversized request line is answered with `oversized` and the
/// connection is closed (the stream cannot be resynchronized).
#[test]
fn oversized_request_line_closes_connection() {
    let (addr, server) = start_server(ServerCfg::default());
    let mut c = Client::connect(addr);
    let huge = "x".repeat(proto::MAX_LINE + 16);
    let r = c.req(&huge);
    assert!(!r.ok);
    assert_eq!(r.code, proto::E_OVERSIZED);
    // connection is gone: the next request gets no reply
    assert!(c.send_raw(r#"{"op": "stats"}"#).is_none());
    // the server itself keeps serving new connections
    let mut c2 = Client::connect(addr);
    c2.ok(r#"{"op": "stats"}"#);
    c2.ok(r#"{"op": "shutdown"}"#);
    server.join().unwrap().unwrap();
}

// ------------------------------------------------- SENG checkpointing

/// SENG's diag/velocity buffers must round-trip through the checkpoint
/// encoding bit-identically: a restored state continues exactly like
/// the uninterrupted one.
#[test]
fn seng_buffers_roundtrip_bit_identically() {
    let mut rng = Rng::new(11);
    let grads: Vec<Vec<f32>> = (0..8)
        .map(|_| (0..10).map(|_| rng.next_gauss() as f32).collect())
        .collect();
    let mut live = SengState::new(2.0, 0.9);
    for g in &grads[..4] {
        let d = live.diag_direction("conv0/w", g);
        live.momentum_step("conv0/w", &d);
        let d = live.diag_direction("bn0/g", &g[..3]);
        live.momentum_step("bn0/g", &d);
    }

    let (diag, vel) = live.snapshot();
    let text = ckpt::seng_state_json(&diag, &vel).to_string_pretty();
    let parsed = Json::parse(&text).unwrap();
    let (diag2, vel2) = ckpt::seng_state_from(Some(&parsed)).unwrap();
    let mut resumed = SengState::new(2.0, 0.9);
    resumed.restore(diag2, vel2);

    for g in &grads[4..] {
        let a = live.diag_direction("conv0/w", g);
        let b = resumed.diag_direction("conv0/w", g);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "diag direction diverged");
        }
        let am = live.momentum_step("conv0/w", &a);
        let bm = resumed.momentum_step("conv0/w", &b);
        for (x, y) in am.iter().zip(&bm) {
            assert_eq!(x.to_bits(), y.to_bits(), "momentum diverged");
        }
    }

    // absent section (version-1.0 checkpoint) decodes to empty buffers
    let (d0, v0) = ckpt::seng_state_from(None).unwrap();
    assert!(d0.is_empty() && v0.is_empty());
}

// ------------------------------------- model sessions (artifact-gated)

fn runtime() -> Option<&'static Runtime> {
    static RT: OnceLock<Option<Runtime>> = OnceLock::new();
    RT.get_or_init(|| {
        let dir = format!("{}/artifacts/tiny", env!("CARGO_MANIFEST_DIR"));
        match Runtime::open(dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping model-session frontend tests ({e:#})");
                None
            }
        }
    })
    .as_ref()
}

fn tiny_dataset(rt: &Runtime) -> Dataset {
    Dataset::generate(DatasetCfg {
        image: rt.manifest.config.image,
        channels: rt.manifest.config.channels,
        n_classes: rt.manifest.config.n_classes,
        n_train: 64,
        n_test: 32,
        seed: 77,
        ..DatasetCfg::default()
    })
}

fn model_state(mgr: &SessionManager, id: u64) -> bnkfac::coordinator::TrainerState {
    match &mgr.session(id).unwrap().work {
        Workload::Model(m) => m.tr.snapshot_state(),
        _ => panic!("expected model session"),
    }
}

/// SENG model session: checkpoint mid-run (momentum buffers included),
/// restore through `SessionManager::restore_model`, and verify the
/// resumed trajectory is bit-identical to the uninterrupted one — the
/// rejection this PR removed from `server/ckpt.rs`.
#[test]
fn seng_model_session_resumes_bit_identically() {
    let Some(rt) = runtime() else { return };
    let cfg = ServerCfg {
        workers: 2,
        max_sessions: 2,
        staleness: 1,
        ..ServerCfg::default()
    };
    let tcfg = TrainerCfg {
        algo: Algo::Seng,
        seed: 9,
        eval_every: 0,
        ..TrainerCfg::default()
    };

    // uninterrupted reference
    let mut reference = SessionManager::with_runtime(cfg.clone(), rt);
    let rid = reference
        .create_model("ref", 1, tcfg.clone(), tiny_dataset(rt), 12, None)
        .unwrap();
    reference.run_to_completion(1_000_000).unwrap();
    let want = model_state(&reference, rid);
    assert!(
        !want.seng_diag.is_empty(),
        "SENG diag buffers missing from trainer state"
    );

    // interrupted: checkpoint at step 5, restore in a fresh server
    let mut mgr = SessionManager::with_runtime(cfg.clone(), rt);
    let id = mgr
        .create_model("x", 1, tcfg, tiny_dataset(rt), 12, None)
        .unwrap();
    while mgr.session(id).unwrap().steps_done() < 5 {
        let st = mgr.run_round().unwrap();
        if st.stepped == 0 {
            std::thread::yield_now();
        }
        assert!(mgr.round < 1_000_000, "stalled before checkpoint");
    }
    let ck = mgr.checkpoint(id).unwrap();
    let text = ck.to_string_pretty();
    assert!(text.contains("\"seng\""), "checkpoint lacks SENG buffers");

    let mut resumed = SessionManager::with_runtime(cfg, rt);
    let j = Json::parse(&text).unwrap();
    assert!(
        resumed.restore(&j, "nope").is_err(),
        "host restore must reject a model checkpoint"
    );
    let rid2 = resumed.restore_model(&j, "x2", tiny_dataset(rt)).unwrap();
    resumed.run_to_completion(1_000_000).unwrap();
    let got = model_state(&resumed, rid2);
    assert_eq!(got.step, want.step);
    assert_eq!(got.rng, want.rng, "rng diverged");
    assert_eq!(got.params, want.params, "params diverged");
    assert_eq!(got.seng_diag, want.seng_diag, "SENG diag diverged");
    assert_eq!(got.seng_velocity, want.seng_velocity, "SENG velocity diverged");
}
