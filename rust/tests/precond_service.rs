//! Integration tests for the async sharded preconditioner service
//! (DESIGN.md §9): the sync-mode bit-match guarantee against the inline
//! decomposition path, the max-staleness bound, and schedule-independent
//! final state in async mode. Everything here runs on the host linalg
//! substrate — no artifacts required.

use std::collections::BTreeMap;

use bnkfac::linalg::Mat;
use bnkfac::optim::factor::{FactorState, Stat};
use bnkfac::optim::{Algo, Hyper, OpRequest, Policy, UpdateOp};
use bnkfac::precond::{PrecondCfg, PrecondService};
use bnkfac::runtime::FactorPlan;
use bnkfac::util::rng::Rng;
use bnkfac::util::timer::PhaseTimers;

fn plan(layer: &str, side: &str, dim: usize, rank: usize, n: usize, brand: bool) -> FactorPlan {
    FactorPlan {
        id: format!("{layer}/{side}"),
        layer: layer.into(),
        kind: "fc".into(),
        side: side.into(),
        dim,
        rank,
        sketch: rank + 4,
        brand,
        n,
        n_crc: (rank / 2).max(1),
        ops: BTreeMap::new(),
    }
}

/// The determinism contract: a sync-mode (staleness 0) service must
/// reproduce the inline trainer decomposition path bit-for-bit over a
/// long multi-factor run covering every op kind the B-KFAC-C policy
/// schedules (RSVD overwrite, Brand, Brand+correction).
#[test]
fn sync_service_bitmatches_inline_over_50_steps() {
    let hyper = Hyper {
        t_updt: 2,
        t_inv: 8,
        t_brand: 4,
        t_rsvd: 16,
        t_corct: 8,
        brand_layer: Some("fc0".into()),
        ..Hyper::default()
    };
    let policy = Policy::new(Algo::BKfacC, hyper);
    let plans = [
        plan("fc0", "A", 24, 6, 3, true),  // brand-managed: Brand + corrections
        plan("fc0", "G", 10, 4, 3, true),  // brand-managed, smaller
        plan("fc1", "A", 16, 5, 3, true),  // not the brand layer → RSVD path
    ];
    let mut t = PhaseTimers::new();
    let mut inline: Vec<FactorState> = plans
        .iter()
        .map(|p| FactorState::new(p.clone(), policy.needs_gram(p)))
        .collect();
    // service side: the trainer keeps Gram authority in its factor
    // states; representations live in (and are published by) the service
    let mut mirrors: Vec<FactorState> = plans
        .iter()
        .map(|p| FactorState::new(p.clone(), policy.needs_gram(p)))
        .collect();
    let svc = PrecondService::new(
        PrecondCfg {
            workers: 2,
            max_staleness: 0,
        },
        plans.iter().map(|p| p.id.clone()).collect(),
    );
    let mut rng_inline = Rng::new(7);
    let mut rng_svc = Rng::new(7);
    let mut data_rng = Rng::new(8);
    let rho = policy.hyper.rho;
    let mut compared = 0usize;
    for k in 0..60usize {
        if k % policy.hyper.t_updt != 0 {
            continue;
        }
        let stats: Vec<Mat> = plans
            .iter()
            .map(|p| Mat::gauss(p.dim, p.n, 1.0, &mut data_rng))
            .collect();
        for (i, f) in inline.iter_mut().enumerate() {
            f.stat_update(&Stat::Raw(&stats[i]), rho, None, &mut t).unwrap();
        }
        for (i, f) in mirrors.iter_mut().enumerate() {
            f.stat_update(&Stat::Raw(&stats[i]), rho, None, &mut t).unwrap();
        }
        for i in 0..plans.len() {
            let op = policy.op_at(k, &plans[i]);
            inline[i]
                .run_op(op, Some(&stats[i]), rho, &policy, None, &mut rng_inline, &mut t)
                .unwrap();
            if let Some(req) = OpRequest::prepare(
                op,
                &plans[i],
                mirrors[i].gram.as_ref(),
                Some(&stats[i]),
                rho,
                &mut rng_svc,
            ) {
                svc.submit(i, req, k as u64, None, &mut t).unwrap();
            }
        }
        for i in 0..plans.len() {
            match (inline[i].rep.as_ref(), svc.cell(i).load_published()) {
                (Some(want), Some(got)) => {
                    assert_eq!(want.u.data, got.rep.u.data, "factor {i} U at step {k}");
                    assert_eq!(want.d, got.rep.d, "factor {i} d at step {k}");
                    compared += 1;
                }
                (None, None) => {}
                (w, g) => panic!(
                    "presence mismatch factor {i} step {k}: inline={} svc={}",
                    w.is_some(),
                    g.is_some()
                ),
            }
        }
    }
    assert!(compared >= 50, "only {compared} comparisons ran");
    // identical RNG consumption on both sides
    assert_eq!(rng_inline.next_u64(), rng_svc.next_u64(), "rng drift");
    svc.drain().unwrap();
}

/// Property: after `enforce_staleness(k)` returns, no factor has an
/// unfinished op older than the configured bound — and because shard
/// queues are FIFO with pre-sampled randomness, the drained final state
/// equals the sequential execution of the same op stream, bit for bit.
#[test]
fn staleness_bound_is_enforced_and_final_state_matches() {
    for &(workers, bound) in &[(2usize, 1u64), (3, 2), (2, 4)] {
        let p = plan("fc0", "A", 20, 5, 3, true);
        let seed = 1000 + workers as u64 * 10 + bound;
        let svc = PrecondService::new(
            PrecondCfg {
                workers,
                max_staleness: bound as usize,
            },
            vec![p.id.clone()],
        );
        let mut rng = Rng::new(seed);
        let mut data_rng = Rng::new(seed + 1);
        let mut t = PhaseTimers::new();
        let mut reqs: Vec<OpRequest> = Vec::new();
        for k in 0..30u64 {
            svc.enforce_staleness(k);
            if let Some(oldest) = svc.cell(0).oldest_pending_step() {
                assert!(
                    k.saturating_sub(oldest) <= bound,
                    "staleness bound {bound} violated at step {k} (oldest {oldest})"
                );
            }
            let stat = Mat::gauss(20, 3, 1.0, &mut data_rng);
            let op = if k == 0 { UpdateOp::Rsvd } else { UpdateOp::Brand };
            let req = OpRequest::prepare(op, &p, None, Some(&stat), 0.9, &mut rng).unwrap();
            reqs.push(req.clone());
            svc.submit(0, req, k, None, &mut t).unwrap();
        }
        svc.drain().unwrap();
        // sequential reference: fold the identical requests in order
        let mut rep = None;
        for r in reqs {
            rep = r.execute(rep, None, &mut t).unwrap();
        }
        let want = rep.expect("stream produces a representation");
        let got = svc.cell(0).load_published().expect("published");
        assert_eq!(got.step, 29);
        assert_eq!(want.u.data, got.rep.u.data, "workers={workers} bound={bound}");
        assert_eq!(want.d, got.rep.d, "workers={workers} bound={bound}");
        assert_eq!(svc.cell(0).pending_len(), 0);
    }
}

/// Elastic-pool regression (DESIGN.md §13.3): shrinking the worker pool
/// in the middle of a live Brand chain — and growing it back later —
/// must not drop, reorder, or restart any queued op. The drained final
/// representation bit-matches both the fixed-pool async run and the
/// sequential fold of the same request stream.
#[test]
fn pool_shrink_mid_brand_chain_bitmatches_fixed_pool() {
    let p = plan("fc0", "A", 24, 6, 3, true);
    let seed = 4242u64;
    let build_reqs = || {
        let mut rng = Rng::new(seed);
        let mut data_rng = Rng::new(seed + 1);
        (0..18u64)
            .map(|k| {
                let stat = Mat::gauss(24, 3, 1.0, &mut data_rng);
                let op = if k == 0 { UpdateOp::Rsvd } else { UpdateOp::Brand };
                OpRequest::prepare(op, &p, None, Some(&stat), 0.9, &mut rng).unwrap()
            })
            .collect::<Vec<_>>()
    };
    let run = |resizes: &[(u64, usize)]| -> (Vec<f32>, Vec<f32>) {
        let svc = PrecondService::new(
            PrecondCfg {
                workers: 4,
                max_staleness: 6,
            },
            vec![p.id.clone()],
        );
        let mut t = PhaseTimers::new();
        for (k, req) in build_reqs().into_iter().enumerate() {
            let k = k as u64;
            for &(at, n) in resizes {
                if at == k {
                    svc.resize_workers(n);
                    assert_eq!(svc.workers(), n);
                }
            }
            svc.enforce_staleness(k);
            svc.submit(0, req, k, None, &mut t).unwrap();
        }
        svc.drain().unwrap();
        let snap = svc.cell(0).load_published().unwrap();
        assert_eq!(snap.step, 17);
        (snap.rep.u.data.clone(), snap.rep.d.clone())
    };
    let fixed = run(&[]);
    let elastic = run(&[(5, 1), (11, 3)]); // shrink mid-chain, grow back
    assert_eq!(fixed.0, elastic.0, "U diverged across a mid-chain resize");
    assert_eq!(fixed.1, elastic.1, "spectrum diverged across a mid-chain resize");

    // sequential reference: the same stream folded in order
    let mut rep = None;
    let mut t = PhaseTimers::new();
    for r in build_reqs() {
        rep = r.execute(rep, None, &mut t).unwrap();
    }
    let want = rep.unwrap();
    assert_eq!(want.u.data, fixed.0);
    assert_eq!(want.d, fixed.1);
}

/// The counters the run log reports must account for every submission.
#[test]
fn service_counters_track_activity() {
    use std::sync::atomic::Ordering::Relaxed;
    let p = plan("fc0", "A", 16, 4, 2, true);
    let svc = PrecondService::new(
        PrecondCfg {
            workers: 2,
            max_staleness: 3,
        },
        vec![p.id.clone()],
    );
    let mut rng = Rng::new(5);
    let mut data_rng = Rng::new(6);
    let mut t = PhaseTimers::new();
    for k in 0..20u64 {
        svc.enforce_staleness(k);
        let stat = Mat::gauss(16, 2, 1.0, &mut data_rng);
        let op = if k == 0 { UpdateOp::Rsvd } else { UpdateOp::Brand };
        let req = OpRequest::prepare(op, &p, None, Some(&stat), 0.9, &mut rng).unwrap();
        svc.submit(0, req, k, None, &mut t).unwrap();
    }
    svc.drain().unwrap();
    let c = svc.counters();
    assert_eq!(c.submitted.load(Relaxed), 20);
    assert_eq!(c.completed.load(Relaxed), 20);
    assert!(c.max_queue_depth.load(Relaxed) >= 1);
    assert!(svc.worker_busy_seconds() >= 0.0);
}
