//! End-to-end acceptance for the `algo = auto` policy engine
//! (DESIGN.md §18): on the smoke scenario the cost-model-driven policy
//! must (a) match or beat the WORST fixed policy on probe-measured
//! inversion error while spending less measured preconditioning time
//! than exact K-FAC, and (b) checkpoint/restore bit-identically across
//! an online rank change — the engine's decisions are a pure function
//! of checkpointed state, so the resumed trajectory (including every
//! later grow/shrink decision) must be indistinguishable from the
//! uninterrupted one.

use bnkfac::optim::{Algo, AutoSpec};
use bnkfac::server::{HostSessionCfg, ServerCfg, SessionManager, Workload};

fn scfg(
    seed: u64,
    algo: Algo,
    steps: u64,
    dim: usize,
    policy: Option<AutoSpec>,
) -> HostSessionCfg {
    HostSessionCfg {
        factors: 2,
        dim,
        rank: 8,
        n_stat: 3,
        grad_cols: 4,
        t_updt: 2,
        algo,
        seed,
        steps,
        rho: 0.95,
        lambda: 0.1,
        policy,
    }
}

fn server_cfg() -> ServerCfg {
    ServerCfg {
        workers: 2,
        max_sessions: 2,
        staleness: 1,
        ..ServerCfg::default()
    }
}

fn host_fingerprint(mgr: &SessionManager, id: u64) -> (Vec<f32>, [u64; 4]) {
    let s = mgr.session(id).expect("session");
    match &s.work {
        Workload::Host(h) => (h.state_vector(), h.rng.state().s),
        _ => panic!("expected host session"),
    }
}

/// Run one session to completion and return (mean probe rel_err,
/// decomposition-worker busy seconds, the session's policy record).
fn run_one(
    algo: Algo,
    policy: Option<AutoSpec>,
) -> (f64, f64, Option<bnkfac::metrics::PolicyRecord>) {
    let mut mgr = SessionManager::new(server_cfg());
    let name = algo.name().to_ascii_lowercase();
    mgr.create_host(&name, 1, scfg(7, algo, 48, 128, policy), None)
        .unwrap();
    mgr.run_to_completion(1_000_000).unwrap();
    let rec = mgr.record();
    let s = &rec.sessions[0];
    assert_eq!(s.status, "Done", "{name} failed: {}", s.error);
    assert!(!s.probes.is_empty(), "{name} recorded no inversion probes");
    let mean = s.probes.iter().map(|p| p.rel_err).sum::<f64>() / s.probes.len() as f64;
    let busy = s.service.as_ref().expect("host service record").worker_busy_s;
    (mean, busy, s.policy.clone())
}

/// The tentpole's quality/cost contract: on identical geometry and
/// seeds, auto's probe-measured inversion error must not exceed the
/// worst fixed policy's, and its measured decomposition time must stay
/// below exact K-FAC's (at d = 128 the d³ EVD dwarfs the sketched and
/// low-rank updates the cost model picks instead).
#[test]
fn auto_matches_fixed_policies_on_error_and_beats_exact_on_cost() {
    let (exact_err, exact_busy, exact_policy) = run_one(Algo::KfacExact, None);
    let (rsvd_err, _, _) = run_one(Algo::RKfac, None);
    let (brand_err, _, _) = run_one(Algo::BKfac, None);
    // err_lo = 0 pins the rank at its floor of the configured rank: the
    // quality comparison measures op selection, not rank shrinkage
    let spec = AutoSpec {
        err_lo: 0.0,
        ..AutoSpec::default()
    };
    let (auto_err, auto_busy, auto_policy) = run_one(Algo::Auto, Some(spec));

    assert!(exact_policy.is_none(), "fixed algo must not carry a policy record");
    let pol = auto_policy.expect("auto session must surface its policy record");
    assert_eq!(pol.factors.len(), 2);
    for f in &pol.factors {
        assert!(
            matches!(f.op.as_str(), "eigh" | "rsvd" | "brand"),
            "unexpected op label {}",
            f.op
        );
        // d = 128 is far past exact_dim_max = 96: the cost model must
        // not have picked the dense EVD
        assert_ne!(f.op, "eigh", "cost model chose eigh at d=128");
        assert!(f.rank >= 2);
    }

    let worst_fixed = exact_err.max(rsvd_err).max(brand_err);
    assert!(
        auto_err <= worst_fixed * 1.05 + 1e-9,
        "auto err {auto_err:.3e} worse than worst fixed policy {worst_fixed:.3e} \
         (exact {exact_err:.3e} rsvd {rsvd_err:.3e} brand {brand_err:.3e})"
    );
    assert!(
        auto_busy < exact_busy,
        "auto spent {auto_busy:.4}s in decompositions, exact K-FAC {exact_busy:.4}s"
    );
}

/// Checkpoint/restore bit-identity ACROSS a rank change (ckpt v1.3):
/// an extreme spec (err_lo = 0.9) forces a deterministic shrink at
/// every cadence boundary, so the checkpoint taken mid-run captures an
/// engine that has already changed ranks and will change them again.
/// The restored session must replay the remaining decisions exactly.
#[test]
fn auto_checkpoint_restores_bit_identically_across_a_rank_change() {
    // every boundary probe reads err << 0.9 => shrink by rank_step
    // until rank_min; dim 48 keeps the run fast
    let spec = AutoSpec {
        err_lo: 0.9,
        err_hi: 0.95,
        ..AutoSpec::default()
    };
    let cfg = |seed| scfg(seed, Algo::Auto, 40, 48, Some(spec.clone()));

    // uninterrupted reference
    let mut reference = SessionManager::new(server_cfg());
    let rid = reference.create_host("ref", 1, cfg(9), None).unwrap();
    reference.run_to_completion(1_000_000).unwrap();
    let want = host_fingerprint(&reference, rid);
    let want_ckpt = reference.checkpoint(rid).unwrap().to_string_pretty();
    let ref_rec = reference.record();
    let pol = ref_rec.sessions[0]
        .policy
        .as_ref()
        .expect("auto session policy record");
    let changes: u64 = pol.factors.iter().map(|f| f.rank_changes).sum();
    assert!(changes >= 1, "forced-shrink spec produced no rank changes");
    assert!(
        pol.factors.iter().all(|f| f.rank < 8),
        "ranks never shrank below the configured rank"
    );

    // interrupted run: checkpoint mid-flight (past the first rank
    // change at the t_inv = 8 boundary), restore, continue
    let mut mgr = SessionManager::new(server_cfg());
    let id = mgr.create_host("x", 1, cfg(9), None).unwrap();
    while mgr.session(id).unwrap().steps_done() < 21 {
        let st = mgr.run_round().unwrap();
        if st.stepped == 0 {
            std::thread::yield_now();
        }
        assert!(mgr.round < 1_000_000, "stalled before checkpoint point");
    }
    let ckpt = mgr.checkpoint(id).unwrap();
    let text = ckpt.to_string_pretty();
    assert!(
        text.contains("\"policy\""),
        "v1.3 checkpoint lacks the policy engine state"
    );
    mgr.run_to_completion(1_000_000).unwrap();
    assert_eq!(
        host_fingerprint(&mgr, id),
        want,
        "checkpointing perturbed the continuing auto run"
    );

    let mut fresh = SessionManager::new(server_cfg());
    let nid = fresh.restore(&ckpt, "restored").unwrap();
    fresh.run_to_completion(1_000_000).unwrap();
    assert_eq!(
        host_fingerprint(&fresh, nid),
        want,
        "restored auto trajectory diverged from the uninterrupted one"
    );
    assert_eq!(
        fresh.checkpoint(nid).unwrap().to_string_pretty(),
        want_ckpt,
        "final checkpoints differ — policy state did not survive the round trip"
    );
}
