//! Observability integration suite (DESIGN.md §14): attaching the
//! structured event journal — and the always-on inversion-error probes —
//! must not perturb a single bit of any session trajectory, while the
//! journal, the latency histograms and the probe samples all actually
//! record the run. Host substrate only — no artifacts needed.

use std::collections::BTreeSet;

use bnkfac::obs::Journal;
use bnkfac::optim::Algo;
use bnkfac::server::{HostSessionCfg, ServerCfg, SessionManager, Workload};
use bnkfac::util::ser::Json;

fn scfg(seed: u64, algo: Algo, steps: u64) -> HostSessionCfg {
    HostSessionCfg {
        factors: 2,
        dim: 36,
        rank: 5,
        n_stat: 3,
        grad_cols: 4,
        t_updt: 2,
        algo,
        seed,
        steps,
        rho: 0.95,
        lambda: 0.1,
        policy: None,
    }
}

fn fingerprint(mgr: &SessionManager, id: u64) -> (Vec<f32>, [u64; 4]) {
    let s = mgr.session(id).expect("session");
    match &s.work {
        Workload::Host(h) => (h.state_vector(), h.rng.state().s),
        _ => panic!("expected host session"),
    }
}

/// Acceptance criterion (ISSUE 6): trace-enabled and trace-disabled
/// runs bit-match, and the trace-enabled run's journal / histograms /
/// probe samples are populated and well-formed.
#[test]
fn tracing_and_probes_do_not_perturb_trajectories() {
    let cfg = ServerCfg {
        workers: 2,
        max_sessions: 4,
        staleness: 1,
        ..ServerCfg::default()
    };

    // reference run: no journal attached
    let mut plain = SessionManager::new(cfg.clone());
    let pa = plain.create_host("a", 2, scfg(11, Algo::BKfacC, 24), None).unwrap();
    let pb = plain.create_host("b", 1, scfg(22, Algo::BKfac, 24), None).unwrap();
    plain.run_to_completion(100_000).unwrap();
    let wa = fingerprint(&plain, pa);
    let wb = fingerprint(&plain, pb);

    // traced run: journal attached before any session exists
    let mut traced = SessionManager::new(cfg);
    let journal = Journal::new(4096);
    traced.set_journal(journal.clone());
    let ta = traced.create_host("a", 2, scfg(11, Algo::BKfacC, 24), None).unwrap();
    let tb = traced.create_host("b", 1, scfg(22, Algo::BKfac, 24), None).unwrap();
    traced.run_to_completion(100_000).unwrap();
    assert_eq!(fingerprint(&traced, ta), wa, "tracing perturbed session a");
    assert_eq!(fingerprint(&traced, tb), wb, "tracing perturbed session b");

    // the journal saw every layer of the run
    let kinds: BTreeSet<&'static str> = journal.snapshot().iter().map(|e| e.kind).collect();
    for want in [
        "session_create",
        "round_start",
        "round_stop",
        "op_submit",
        "op_drain",
        "op_publish",
    ] {
        assert!(kinds.contains(want), "journal missing '{want}': {kinds:?}");
    }

    // the export is valid JSONL with a loss-accounting summary tail
    let out = journal.export_jsonl();
    let mut summary = None;
    for line in out.lines() {
        let j = Json::parse(line).expect("every exported line parses");
        assert!(j.get("event").is_some(), "{line}");
        if j.get("event").and_then(|v| v.as_str()) == Some("journal_summary") {
            summary = Some(j);
        }
    }
    let summary = summary.expect("trailing journal_summary line");
    assert!(summary.get("recorded").and_then(|v| v.as_usize()).unwrap() > 0);
    assert!(summary.get("dropped").is_some());

    // histograms + correlation stamps + probe samples in the record
    let rec = traced.record();
    assert!(rec.round > 0, "round stamp missing");
    assert!(rec.round_ms.count() > 0, "round-duration histogram empty");
    let a = rec
        .sessions
        .iter()
        .find(|s| s.name == "a")
        .expect("session a in record");
    assert!(!a.probes.is_empty(), "no inversion-error probe samples");
    for p in &a.probes {
        assert!(
            p.rel_err.is_finite() && p.rel_err >= 0.0,
            "bad probe residual {p:?}"
        );
        assert!(!p.layer.is_empty() && !p.kind.is_empty(), "{p:?}");
        assert!(p.rank > 0, "{p:?}");
    }
    let svc = a.service.as_ref().expect("per-session service record");
    assert!(svc.apply_ms.count() > 0, "apply-latency histogram empty");
    assert!(
        svc.op_ms.iter().any(|(_, h)| h.count() > 0),
        "per-kind inverse-update histograms all empty: {:?}",
        svc.op_ms.iter().map(|(k, h)| (k.clone(), h.count())).collect::<Vec<_>>()
    );
}

/// Checkpoints taken under tracing are byte-identical to checkpoints
/// of an untraced run (probe/journal state must never leak into the
/// checkpoint format), and a traced restore resumes bit-identically.
#[test]
fn checkpoints_are_identical_with_and_without_tracing() {
    let cfg = ServerCfg {
        workers: 2,
        max_sessions: 2,
        staleness: 1,
        ..ServerCfg::default()
    };
    let run_to_ckpt = |traced: bool| {
        let mut mgr = SessionManager::new(cfg.clone());
        if traced {
            mgr.set_journal(Journal::new(512));
        }
        let id = mgr.create_host("c", 1, scfg(9, Algo::BKfacC, 40), None).unwrap();
        while mgr.session(id).unwrap().steps_done() < 21 {
            let st = mgr.run_round().unwrap();
            if st.stepped == 0 {
                std::thread::yield_now();
            }
            assert!(mgr.round < 1_000_000, "stalled before checkpoint point");
        }
        let ck = mgr.checkpoint(id).unwrap();
        mgr.run_to_completion(100_000).unwrap();
        (ck, fingerprint(&mgr, id))
    };
    let (ck_plain, fp_plain) = run_to_ckpt(false);
    let (ck_traced, fp_traced) = run_to_ckpt(true);
    assert_eq!(fp_traced, fp_plain, "tracing perturbed the interrupted run");
    assert_eq!(
        ck_traced.to_string_compact(),
        ck_plain.to_string_compact(),
        "tracing/probe state leaked into the checkpoint"
    );

    // a traced restore of the traced checkpoint still lands on the
    // untraced trajectory
    let mut resumed = SessionManager::new(cfg.clone());
    resumed.set_journal(Journal::new(512));
    let rid = resumed.restore(&ck_traced, "c2").unwrap();
    resumed.run_to_completion(100_000).unwrap();
    assert_eq!(fingerprint(&resumed, rid), fp_plain, "traced resume diverged");
}

/// Probe samples are themselves deterministic: two identical traced
/// runs record identical probe sequences (same layers, kinds, steps and
/// bit-identical residuals).
#[test]
fn probe_samples_are_reproducible_run_to_run() {
    let run = || {
        let mut mgr = SessionManager::new(ServerCfg {
            workers: 1,
            max_sessions: 2,
            staleness: 0,
            ..ServerCfg::default()
        });
        let id = mgr.create_host("p", 1, scfg(77, Algo::BKfacC, 24), None).unwrap();
        mgr.run_to_completion(100_000).unwrap();
        let rec = mgr.record();
        let _ = id;
        rec.sessions[0].probes.clone()
    };
    let one = run();
    let two = run();
    assert!(!one.is_empty(), "no probe samples recorded");
    assert_eq!(one.len(), two.len());
    for (x, y) in one.iter().zip(&two) {
        assert_eq!(x.layer, y.layer);
        assert_eq!(x.kind, y.kind);
        assert_eq!(x.step, y.step);
        assert_eq!(
            x.rel_err.to_bits(),
            y.rel_err.to_bits(),
            "probe residual not bit-reproducible for {}",
            x.layer
        );
    }
}
