//! Artifact ⇄ host-linalg cross-validation: every XLA artifact must agree
//! with the pure-rust oracle on the same inputs (the two implementations
//! are written independently — python/jax vs rust — so agreement is a
//! strong end-to-end correctness signal for BOTH).

use std::sync::OnceLock;

use bnkfac::linalg::{LowRank, Mat, RsvdOpts};
use bnkfac::runtime::{Runtime, Value};
use bnkfac::util::rng::Rng;

/// None when the artifact bundle / PJRT runtime is unavailable (offline
/// builds use the vendor xla stub) — each test then skips gracefully.
fn runtime() -> Option<&'static Runtime> {
    static RT: OnceLock<Option<Runtime>> = OnceLock::new();
    RT.get_or_init(|| {
        let dir = format!("{}/artifacts/tiny", env!("CARGO_MANIFEST_DIR"));
        match Runtime::open(dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!(
                    "skipping artifact-backed tests ({e:#}); run `make \
                     artifacts` with the real xla bindings to enable"
                );
                None
            }
        }
    })
    .as_ref()
}

/// tiny config fc0: d_a = 129, rank 16, batch 8, sketch 22.
const D: usize = 129;
const R: usize = 16;
const N: usize = 8;
const K: usize = 22;

#[test]
fn syrk_ea_artifact_matches_host() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(1);
    let m = Mat::psd_with_decay(D, 0.9, &mut rng);
    let a = Mat::gauss(D, N, 1.0, &mut rng);
    let rho = 0.95f32;
    let outs = rt
        .exec(
            "syrk_ea_129x8",
            &[Value::M(m.clone()), Value::M(a.clone()), Value::S(rho)],
        )
        .unwrap();
    let got = outs[0].as_mat();
    let mut want = a.syrk().scale(1.0 - rho);
    want.axpy_inplace(rho, &m);
    assert!(got.rel_err(&want) < 1e-4, "rel err {}", got.rel_err(&want));
}

#[test]
fn rsvd_stages_match_host_rsvd() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(2);
    let m = Mat::psd_with_decay(D, 0.8, &mut rng);
    let omega = Mat::gauss(D, K, 1.0, &mut rng);
    // artifact path
    let outs = rt
        .exec("rsvd_p1_129_22", &[Value::M(m.clone()), Value::M(omega.clone())])
        .unwrap();
    let q = outs[0].as_mat().clone();
    let s = outs[1].as_mat();
    let ev = s.eigh();
    let u_s = ev.u.slice_cols(0, R);
    let outs = rt
        .exec("tmm_129_22_16", &[Value::M(q), Value::M(u_s)])
        .unwrap();
    let u = outs[0].as_mat().clone();
    let art = LowRank::new(u, ev.d[..R].iter().map(|&x| x.max(0.0)).collect());
    // host path, same sketch
    let host = m.rsvd_with_sketch(
        &omega,
        RsvdOpts {
            rank: R,
            oversample: K - R,
            n_pwr: 2, // tiny config n_pwr
        },
    );
    // same subspace => same reconstruction (vectors may differ by sign)
    let da = art.to_dense();
    let dh = host.to_dense();
    assert!(da.rel_err(&dh) < 1e-3, "rel err {}", da.rel_err(&dh));
    // and both approximate M well
    assert!(da.rel_err(&m) < 0.25);
}

#[test]
fn brand_stages_match_host_brand() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(3);
    // start from an RSVD-style rep of a PSD matrix
    let m = Mat::psd_with_decay(D, 0.8, &mut rng);
    let rep = LowRank::from_eigh(&m.eigh(), R);
    let a = Mat::gauss(D, N, 0.7, &mut rng);
    let rho = 0.95f32;
    // artifact path: p1 -> host EVD -> p2
    let outs = rt
        .exec(
            "brand_p1_129_16_8",
            &[
                Value::M(rep.u.clone()),
                Value::V(rep.d.clone()),
                Value::M(a.clone()),
                Value::S(rho),
            ],
        )
        .unwrap();
    let m_s = outs[0].as_mat();
    let q_a = outs[1].as_mat().clone();
    assert_eq!((m_s.rows, m_s.cols), (R + N, R + N));
    let ev = m_s.eigh();
    let outs = rt
        .exec(
            "brand_p2_129_16_8",
            &[Value::M(rep.u.clone()), Value::M(q_a), Value::M(ev.u.clone())],
        )
        .unwrap();
    let u_new = outs[0].as_mat().clone();
    let art = LowRank::new(u_new, ev.d.iter().map(|&x| x.max(0.0)).collect());
    // host path
    let host = rep.brand_ea_update(&a, rho, R);
    let (da, dh) = (art.to_dense(), host.to_dense());
    assert!(da.rel_err(&dh) < 1e-3, "rel err {}", da.rel_err(&dh));
    // exactness vs direct formula
    let want = rep.to_dense().scale(rho).add(&a.syrk().scale(1.0 - rho));
    assert!(da.rel_err(&want) < 1e-3, "vs formula {}", da.rel_err(&want));
}

#[test]
fn correction_stages_match_host_correction() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(4);
    let m = Mat::psd_with_decay(D, 0.8, &mut rng);
    // rep of width R+N (post-Brand width, what corr artifacts expect)
    let rep = LowRank::from_eigh(&m.eigh(), R + N);
    // perturb it so there is something to correct
    let noisy = {
        let mut u = rep.u.clone();
        let noise = Mat::gauss(D, R + N, 0.05, &mut rng);
        u.axpy_inplace(1.0, &noise);
        let (q, _) = u.qr();
        LowRank::new(q, rep.d.clone())
    };
    let c = 8; // tiny config n_crc = phi 0.5 * rank 16
    let mut rng_idx = Rng::new(99);
    let idx = rng_idx.choose(R + N, c);
    let idx_i32: Vec<i32> = idx.iter().map(|&i| i as i32).collect();
    // artifact path
    let outs = rt
        .exec(
            "corr_p1_129_24_8",
            &[
                Value::M(noisy.u.clone()),
                Value::M(m.clone()),
                Value::I(idx_i32.clone()),
            ],
        )
        .unwrap();
    let u_c = outs[0].as_mat().clone();
    let m_s = outs[1].as_mat();
    let ev = m_s.eigh();
    let outs = rt
        .exec(
            "corr_p2_129_24_8",
            &[
                Value::M(noisy.u.clone()),
                Value::M(u_c),
                Value::M(ev.u.clone()),
                Value::I(idx_i32),
            ],
        )
        .unwrap();
    let u_new = outs[0].as_mat().clone();
    let mut d_new = noisy.d.clone();
    for (jj, &j) in idx.iter().enumerate() {
        d_new[j] = ev.d[jj].max(0.0);
    }
    let art = LowRank::new(u_new, d_new);
    // host path (same indices)
    let host = noisy.correction(&m, &idx);
    assert!(
        art.to_dense().rel_err(&host.to_dense()) < 1e-3,
        "rel err {}",
        art.to_dense().rel_err(&host.to_dense())
    );
    // correction must not increase the error (paper footnote 11)
    let before = noisy.to_dense().sub(&m).fro_norm();
    let after = art.to_dense().sub(&m).fro_norm();
    assert!(after <= before + 1e-3, "{before} -> {after}");
}

#[test]
fn precond_artifact_matches_host_apply() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(5);
    // fc0 layer in tiny: d_a=129, d_g=32, k_pad=24
    let (d_a, d_g, k_pad) = (129usize, 32usize, 24usize);
    let ma = Mat::psd_with_decay(d_a, 0.8, &mut rng);
    let mg = Mat::psd_with_decay(d_g, 0.8, &mut rng);
    let ra = LowRank::from_eigh(&ma.eigh(), k_pad);
    let rg = LowRank::from_eigh(&mg.eigh(), k_pad);
    let grad = Mat::gauss(d_a, d_g, 1.0, &mut rng);
    let (lam_a, lam_g) = (0.3f32, 0.2f32);
    let outs = rt
        .exec(
            "precond_32_129_24",
            &[
                Value::M(rg.u.clone()),
                Value::V(rg.d.clone()),
                Value::S(lam_g),
                Value::M(ra.u.clone()),
                Value::V(ra.d.clone()),
                Value::S(lam_a),
                Value::M(grad.clone()),
            ],
        )
        .unwrap();
    let got = outs[0].as_mat();
    let m1 = ra.apply_inv_left(&grad, lam_a, false);
    let want = rg.apply_inv_right(&m1, lam_g, false);
    assert!(got.rel_err(&want) < 1e-3, "rel err {}", got.rel_err(&want));
}

#[test]
fn linear_apply_artifact_matches_host() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(6);
    let (d_a, d_g, k_pad, n) = (129usize, 32usize, 24usize, 8usize);
    let ma = Mat::psd_with_decay(d_a, 0.8, &mut rng);
    let mg = Mat::psd_with_decay(d_g, 0.8, &mut rng);
    let ra = LowRank::from_eigh(&ma.eigh(), k_pad);
    let rg = LowRank::from_eigh(&mg.eigh(), k_pad);
    let a_stat = Mat::gauss(d_a, n, 1.0, &mut rng);
    let g_stat = Mat::gauss(d_g, n, 1.0, &mut rng);
    let (lam_a, lam_g) = (0.5f32, 0.4f32);
    let outs = rt
        .exec(
            "linear_apply_32_129_24_8",
            &[
                Value::M(rg.u.clone()),
                Value::V(rg.d.clone()),
                Value::S(lam_g),
                Value::M(ra.u.clone()),
                Value::V(ra.d.clone()),
                Value::S(lam_a),
                Value::M(a_stat.clone()),
                Value::M(g_stat.clone()),
            ],
        )
        .unwrap();
    let got = outs[0].as_mat();
    let g_pre = rg.apply_inv_left(&g_stat, lam_g, false);
    let at_pre = ra.apply_inv_right(&a_stat.transpose(), lam_a, false);
    let want = g_pre.matmul(&at_pre).transpose();
    assert!(got.rel_err(&want) < 1e-3, "rel err {}", got.rel_err(&want));
}

#[test]
fn train_step_artifact_runs_and_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(7);
    let manifest = &rt.manifest;
    let params = bnkfac::model::ParamStore::init(manifest, &mut rng);
    let b = manifest.config.batch;
    let img = manifest.config.image;
    let ch = manifest.config.channels;
    let mut x = vec![0.0f32; b * img * img * ch];
    rng.fill_gauss(&mut x);
    let y: Vec<i32> = (0..b as i32).map(|i| i % 10).collect();
    let run = || {
        let mut inputs = params.as_values();
        inputs.push(Value::T(x.clone(), vec![b, img, img, ch]));
        inputs.push(Value::I(y.clone()));
        rt.exec("train_step", &inputs).unwrap()
    };
    let o1 = run();
    let o2 = run();
    assert_eq!(o1[0].as_scalar(), o2[0].as_scalar(), "loss deterministic");
    assert!(o1[0].as_scalar().is_finite());
    // grads deterministic too
    assert_eq!(o1[2].as_mat().data, o2[2].as_mat().data);
}

#[test]
fn exec_rejects_wrong_arity_and_shape() {
    let Some(rt) = runtime() else { return };
    assert!(rt.exec("syrk_ea_129x8", &[]).is_err());
    let bad = Mat::zeros(3, 3);
    assert!(rt
        .exec(
            "syrk_ea_129x8",
            &[Value::M(bad.clone()), Value::M(bad), Value::S(0.5)]
        )
        .is_err());
    assert!(rt.exec("nonexistent_artifact", &[]).is_err());
}
