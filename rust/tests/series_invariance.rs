//! Time-series sampling invariance (DESIGN.md §15.1): attaching the
//! rolling series store — even at its most aggressive every-round
//! cadence, on top of a journal — must not perturb a single bit of any
//! session trajectory, while the sampled points actually carry the
//! fleet signals. The §15 counterpart of `obs_trace.rs`. Host
//! substrate only — no artifacts needed.

use bnkfac::obs::{Journal, SeriesStore};
use bnkfac::optim::Algo;
use bnkfac::server::{HostSessionCfg, ServerCfg, SessionManager, Workload};
use bnkfac::util::ser::Json;

fn scfg(seed: u64, algo: Algo, steps: u64) -> HostSessionCfg {
    HostSessionCfg {
        factors: 2,
        dim: 36,
        rank: 5,
        n_stat: 3,
        grad_cols: 4,
        t_updt: 2,
        algo,
        seed,
        steps,
        rho: 0.95,
        lambda: 0.1,
        policy: None,
    }
}

fn fingerprint(mgr: &SessionManager, id: u64) -> (Vec<f32>, [u64; 4]) {
    let s = mgr.session(id).expect("session");
    match &s.work {
        Workload::Host(h) => (h.state_vector(), h.rng.state().s),
        _ => panic!("expected host session"),
    }
}

/// Acceptance criterion (ISSUE 7): a traced + series-sampled run's
/// session trajectories bit-match an untraced solo run, and the series
/// window actually recorded the fleet signals.
#[test]
fn series_sampling_does_not_perturb_trajectories() {
    let cfg = ServerCfg {
        workers: 2,
        max_sessions: 4,
        staleness: 1,
        ..ServerCfg::default()
    };

    // reference run: no observability attached at all
    let mut plain = SessionManager::new(cfg.clone());
    let pa = plain.create_host("a", 2, scfg(11, Algo::BKfacC, 24), None).unwrap();
    let pb = plain.create_host("b", 1, scfg(22, Algo::BKfac, 24), None).unwrap();
    plain.run_to_completion(100_000).unwrap();
    let wa = fingerprint(&plain, pa);
    let wb = fingerprint(&plain, pb);

    // observed run: journal AND an every-round series store attached
    // before any session exists — the heaviest observation the server
    // supports
    let mut observed = SessionManager::new(cfg);
    observed.set_journal(Journal::new(4096));
    let series = SeriesStore::new(1024, 1);
    observed.set_series(series.clone());
    let ta = observed.create_host("a", 2, scfg(11, Algo::BKfacC, 24), None).unwrap();
    let tb = observed.create_host("b", 1, scfg(22, Algo::BKfac, 24), None).unwrap();
    observed.run_to_completion(100_000).unwrap();
    assert_eq!(fingerprint(&observed, ta), wa, "series sampling perturbed session a");
    assert_eq!(fingerprint(&observed, tb), wb, "series sampling perturbed session b");

    // the window recorded real points with the fleet signals on board
    assert!(series.recorded() > 0, "no series points recorded");
    let points = series.snapshot();
    assert!(!points.is_empty());
    let mut last_round = 0u64;
    for p in &points {
        for key in [
            "round",
            "t_ms",
            "stepped",
            "sessions",
            "running",
            "queue_depth",
            "workers",
            "resident_total_mb",
        ] {
            assert!(
                p.get(key).and_then(|v| v.as_f64()).is_some(),
                "point missing numeric '{key}': {p:?}"
            );
        }
        let round = p.get("round").and_then(|v| v.as_usize()).unwrap() as u64;
        assert!(round > last_round, "rounds not strictly increasing");
        last_round = round;
        assert!(
            p.get("resident_mb").map(|m| matches!(m, Json::Obj(_))).unwrap_or(false),
            "per-session resident_mb map missing: {p:?}"
        );
        // histogram columns are per-window deltas, present on every point
        for key in ["round_ms", "op_ms"] {
            assert!(p.get(key).is_some(), "point missing '{key}' delta: {p:?}");
        }
    }
    // round_ms deltas across the window sum to ~one sample per sampled
    // round (every-round cadence: one round duration lands per point,
    // modulo the rounds after the final sample)
    let delta_total: usize = points
        .iter()
        .filter_map(|p| p.at(&["round_ms", "count"]))
        .filter_map(|v| v.as_usize())
        .sum();
    assert!(delta_total > 0, "round_ms deltas never carried a sample");

    // the export contract matches the journal's: JSONL + summary tail
    let out = series.export_jsonl();
    let lines: Vec<&str> = out.lines().collect();
    assert_eq!(lines.len(), points.len() + 1);
    let tail = Json::parse(lines[lines.len() - 1]).unwrap();
    assert_eq!(tail.get("event").and_then(|v| v.as_str()), Some("series_summary"));
    assert_eq!(
        tail.get("recorded").and_then(|v| v.as_usize()).unwrap() as u64,
        series.recorded()
    );
}

/// The ring is bounded: a tiny capacity under an every-round cadence
/// must slide the window (oldest out) and account for every dropped
/// point, never grow or block.
#[test]
fn series_ring_is_bounded_with_drop_accounting() {
    let mut mgr = SessionManager::new(ServerCfg {
        workers: 1,
        max_sessions: 2,
        staleness: 0,
        ..ServerCfg::default()
    });
    let series = SeriesStore::new(4, 1);
    mgr.set_series(series.clone());
    mgr.create_host("c", 1, scfg(9, Algo::BKfacC, 24), None).unwrap();
    mgr.run_to_completion(100_000).unwrap();

    assert!(series.recorded() > 4, "run too short to overflow the ring");
    assert_eq!(series.len(), 4, "ring grew past its capacity");
    assert_eq!(
        series.dropped(),
        series.recorded() - 4,
        "overflow drops not accounted"
    );
    // the surviving window is the most recent points, oldest first
    let rounds: Vec<usize> = series
        .snapshot()
        .iter()
        .map(|p| p.get("round").and_then(|v| v.as_usize()).unwrap())
        .collect();
    let mut sorted = rounds.clone();
    sorted.sort_unstable();
    assert_eq!(rounds, sorted, "window not oldest-first");
}
