//! Integration tests for the multi-tenant session server (DESIGN.md §11):
//! session isolation under the shared pool, checkpoint/resume
//! bit-identity (including an in-flight Brand chain), admission control,
//! fair-share non-starvation under flooding, and graceful shutdown of a
//! service dropped mid-queue. Host substrate only — no artifacts needed.

use std::collections::BTreeMap;
use std::sync::Arc;

use bnkfac::linalg::Mat;
use bnkfac::optim::{Algo, OpRequest, UpdateOp};
use bnkfac::precond::{PrecondCfg, PrecondService};
use bnkfac::runtime::FactorPlan;
use bnkfac::server::{
    FairScheduler, HostSessionCfg, QuotaSpec, ServerCfg, SessionManager, SessionStatus,
    Workload,
};
use bnkfac::util::rng::Rng;
use bnkfac::util::threadpool::WorkerPool;
use bnkfac::util::timer::PhaseTimers;

fn scfg(seed: u64, algo: Algo, steps: u64) -> HostSessionCfg {
    HostSessionCfg {
        factors: 2,
        dim: 36,
        rank: 5,
        n_stat: 3,
        grad_cols: 4,
        t_updt: 2,
        algo,
        seed,
        steps,
        rho: 0.95,
        lambda: 0.1,
        policy: None,
    }
}

fn host_fingerprint(mgr: &SessionManager, id: u64) -> (Vec<f32>, [u64; 4]) {
    let s = mgr.session(id).expect("session");
    match &s.work {
        Workload::Host(h) => (h.state_vector(), h.rng.state().s),
        _ => panic!("expected host session"),
    }
}

/// Two sessions interleaved on one shared pool must produce EXACTLY the
/// state each produces when run alone — tenant isolation is bit-level.
#[test]
fn interleaved_sessions_bitmatch_solo_runs() {
    let cfg = ServerCfg {
        workers: 2,
        max_sessions: 4,
        staleness: 1,
        ..ServerCfg::default()
    };
    let mut mgr = SessionManager::new(cfg.clone());
    let a = mgr.create_host("a", 2, scfg(11, Algo::BKfac, 20), None).unwrap();
    let b = mgr.create_host("b", 1, scfg(22, Algo::BKfacC, 20), None).unwrap();
    mgr.run_to_completion(100_000).unwrap();
    let fa = host_fingerprint(&mgr, a);
    let fb = host_fingerprint(&mgr, b);

    for (seed, algo, want) in [(11, Algo::BKfac, &fa), (22, Algo::BKfacC, &fb)] {
        let mut solo = SessionManager::new(cfg.clone());
        let id = solo.create_host("solo", 1, scfg(seed, algo, 20), None).unwrap();
        solo.run_to_completion(100_000).unwrap();
        let f = host_fingerprint(&solo, id);
        assert_eq!(f.0, want.0, "state diverged for seed {seed}");
        assert_eq!(f.1, want.1, "rng diverged for seed {seed}");
    }

    let rec = mgr.record();
    assert_eq!(rec.total_steps, 40);
    assert!(rec.fairness_jain > 0.0 && rec.fairness_jain <= 1.0 + 1e-12);
    for s in &rec.sessions {
        assert_eq!(s.submitted, s.completed, "ops lost for {}", s.name);
        assert_eq!(s.status, "Done");
    }
}

/// Checkpoint a session mid-run (with a live Brand chain), restore it in
/// a fresh server, and run both to completion: the resumed trajectory
/// must be bit-identical to the uninterrupted one.
#[test]
fn checkpoint_restore_resumes_bit_identically() {
    let cfg = ServerCfg {
        workers: 2,
        max_sessions: 2,
        staleness: 1,
        ..ServerCfg::default()
    };
    // uninterrupted reference
    let mut reference = SessionManager::new(cfg.clone());
    let rid = reference
        .create_host("ref", 1, scfg(7, Algo::BKfac, 40), None)
        .unwrap();
    reference.run_to_completion(100_000).unwrap();
    let want = host_fingerprint(&reference, rid);

    // interrupted run: checkpoint mid-flight, then continue
    let mut mgr = SessionManager::new(cfg.clone());
    let id = mgr.create_host("x", 1, scfg(7, Algo::BKfac, 40), None).unwrap();
    while mgr.session(id).unwrap().steps_done() < 21 {
        let st = mgr.run_round().unwrap();
        if st.stepped == 0 {
            std::thread::yield_now();
        }
        assert!(mgr.round < 1_000_000, "stalled before checkpoint point");
    }
    let ckpt = mgr.checkpoint(id).unwrap();
    // the Brand chain must actually be in the checkpoint by step 21
    let text = ckpt.to_string_pretty();
    assert!(text.contains("\"chain\""), "checkpoint lacks chain state");
    mgr.run_to_completion(100_000).unwrap();
    assert_eq!(
        host_fingerprint(&mgr, id),
        want,
        "checkpointing perturbed the continuing run"
    );

    // resumed run in a fresh server
    let mut resumed = SessionManager::new(cfg);
    let rid2 = resumed.restore(&ckpt, "x-resumed").unwrap();
    let at_restore = resumed.session(rid2).unwrap().steps_done();
    assert!((21..40).contains(&at_restore), "bad resume point {at_restore}");
    resumed.run_to_completion(100_000).unwrap();
    assert_eq!(
        host_fingerprint(&resumed, rid2),
        want,
        "resumed trajectory diverged"
    );
}

#[test]
fn admission_control_rejects_past_capacity() {
    let mut mgr = SessionManager::new(ServerCfg {
        workers: 1,
        max_sessions: 2,
        staleness: 1,
        ..ServerCfg::default()
    });
    let a = mgr.create_host("a", 1, scfg(1, Algo::BKfac, 8), None).unwrap();
    let _b = mgr.create_host("b", 1, scfg(2, Algo::BKfac, 8), None).unwrap();
    let err = mgr.create_host("c", 1, scfg(3, Algo::BKfac, 8), None);
    assert!(err.is_err(), "third session admitted past capacity 2");
    // dropping one frees the slot
    mgr.drop_session(a).unwrap();
    mgr.create_host("c", 1, scfg(3, Algo::BKfac, 8), None).unwrap();
    mgr.run_to_completion(100_000).unwrap();
}

#[test]
fn pause_resume_lifecycle() {
    let mut mgr = SessionManager::new(ServerCfg {
        workers: 1,
        max_sessions: 2,
        staleness: 1,
        ..ServerCfg::default()
    });
    let id = mgr.create_host("p", 1, scfg(5, Algo::BKfac, 10), None).unwrap();
    mgr.run_round().unwrap();
    mgr.pause(id).unwrap();
    let before = mgr.session(id).unwrap().steps_done();
    for _ in 0..5 {
        mgr.run_round().unwrap();
    }
    assert_eq!(
        mgr.session(id).unwrap().steps_done(),
        before,
        "paused session stepped"
    );
    assert_eq!(mgr.session(id).unwrap().status, SessionStatus::Paused);
    mgr.resume(id).unwrap();
    mgr.run_to_completion(100_000).unwrap();
    assert_eq!(mgr.session(id).unwrap().steps_done(), 10);
}

/// One tenant's decomposition chain failing must mark THAT session
/// Failed (error recorded) while every other tenant completes — the
/// failure-containment half of the isolation contract.
#[test]
fn session_failure_is_contained() {
    let mut mgr = SessionManager::new(ServerCfg {
        workers: 1,
        max_sessions: 2,
        staleness: 1,
        ..ServerCfg::default()
    });
    let bad = mgr.create_host("bad", 1, scfg(41, Algo::BKfac, 12), None).unwrap();
    let good = mgr.create_host("good", 1, scfg(42, Algo::BKfac, 12), None).unwrap();
    // poison the bad session's first cell: a Brand op with no predecessor
    // representation errors on the worker and fails the chain
    {
        let svc = mgr.session(bad).unwrap().svc.as_ref().unwrap();
        let req = OpRequest {
            op: UpdateOp::Brand,
            plan: heavy_plan("f0/A", 36),
            gram: None,
            raw_stat: Some(Mat::zeros(36, 2)),
            omega: None,
            corr_idx: None,
            rho: 0.9,
        };
        let mut t = PhaseTimers::new();
        svc.submit(0, req, 0, None, &mut t).unwrap();
    }
    mgr.run_to_completion(100_000).unwrap();
    let b = mgr.session(bad).unwrap();
    assert_eq!(b.status, SessionStatus::Failed, "poisoned session not Failed");
    assert!(b.error.is_some(), "failure not recorded");
    let g = mgr.session(good).unwrap();
    assert_eq!(g.status, SessionStatus::Done, "healthy tenant was taken down");
    assert_eq!(g.steps_done(), 12);
}

fn heavy_plan(id: &str, dim: usize) -> FactorPlan {
    FactorPlan {
        id: id.into(),
        layer: "l".into(),
        kind: "fc".into(),
        side: "A".into(),
        dim,
        rank: 16,
        sketch: 20,
        brand: true,
        n: 4,
        n_crc: 8,
        ops: BTreeMap::new(),
    }
}

fn heavy_rsvd(plan: &FactorPlan, gram: &Mat, rng: &mut Rng) -> OpRequest {
    OpRequest::prepare(UpdateOp::Rsvd, plan, Some(gram), None, 0.9, rng).unwrap()
}

/// A tenant submitting one op must not wait behind another tenant's
/// entire backlog — the scheduler serves the newcomer within its fair
/// share (the end-to-end counterpart of the unit-level proptest).
#[test]
fn fair_share_newcomer_is_not_starved_by_flood() {
    let pool = Arc::new(WorkerPool::new(1));
    let sched = Arc::new(FairScheduler::new());
    sched.register(1, 1);
    sched.register(2, 1);
    let plan = heavy_plan("flood/A", 160);
    let cfg = PrecondCfg {
        workers: 1,
        max_staleness: 64,
    };
    let svc_flood = PrecondService::shared(
        cfg.clone(),
        vec!["flood/A".into()],
        pool.clone(),
        sched.clone(),
        1,
    );
    let svc_small = PrecondService::shared(
        cfg,
        vec!["small/A".into()],
        pool.clone(),
        sched.clone(),
        2,
    );
    let mut rng = Rng::new(3);
    let gram = Mat::psd_with_decay(160, 0.7, &mut rng);
    let mut t = PhaseTimers::new();
    for k in 0..24u64 {
        svc_flood
            .submit(0, heavy_rsvd(&plan, &gram, &mut rng), k, None, &mut t)
            .unwrap();
    }
    let small_plan = heavy_plan("small/A", 160);
    svc_small
        .submit(0, heavy_rsvd(&small_plan, &gram, &mut rng), 0, None, &mut t)
        .unwrap();
    svc_small.drain().unwrap();
    let flood_done = svc_flood
        .counters()
        .completed
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(
        flood_done < 20,
        "newcomer waited behind {flood_done}/24 flood ops — not fair-shared"
    );
    svc_flood.drain().unwrap();
}

/// Regression (graceful shutdown): dropping a service mid-queue cancels
/// the unstarted backlog and joins the drainer threads instead of
/// leaking them or draining everything first.
#[test]
fn dropping_service_mid_queue_cancels_and_joins() {
    let plan = heavy_plan("big/A", 220);
    let svc = PrecondService::new(
        PrecondCfg {
            workers: 1,
            max_staleness: 32,
        },
        vec!["big/A".into()],
    );
    let mut rng = Rng::new(9);
    let gram = Mat::psd_with_decay(220, 0.7, &mut rng);
    let mut t = PhaseTimers::new();
    for k in 0..12u64 {
        svc.submit(0, heavy_rsvd(&plan, &gram, &mut rng), k, None, &mut t)
            .unwrap();
    }
    let counters = svc.counters().clone();
    drop(svc); // cancels queued ops, then joins the pool threads
    use std::sync::atomic::Ordering::Relaxed;
    let completed = counters.completed.load(Relaxed);
    assert_eq!(counters.submitted.load(Relaxed), 12);
    assert!(
        completed < 12,
        "drop drained the whole backlog instead of cancelling ({completed}/12)"
    );
}

/// Dropping a whole manager with live sessions and queued ops must
/// return promptly (threads joined, queue cancelled) — regression for
/// the drop-ordering contract.
#[test]
fn dropping_manager_mid_run_is_clean() {
    let mut mgr = SessionManager::new(ServerCfg {
        workers: 2,
        max_sessions: 4,
        staleness: 1,
        ..ServerCfg::default()
    });
    let big = HostSessionCfg {
        dim: 180,
        rank: 16,
        steps: 50,
        ..scfg(31, Algo::BKfac, 50)
    };
    mgr.create_host("m1", 1, big.clone(), None).unwrap();
    mgr.create_host("m2", 1, HostSessionCfg { seed: 32, ..big }, None)
        .unwrap();
    for _ in 0..6 {
        mgr.run_round().unwrap();
    }
    drop(mgr); // must not hang or leak threads
}

// ------------------------------------------------ resource governor e2e

/// The PR's acceptance scenario: an over-quota flood session walks the
/// governor's throttle → pause → evict ladder while a compliant tenant
/// on the same pool completes with its solo-run bit-identical result.
#[test]
fn over_quota_flood_is_evicted_compliant_session_bitmatches_solo() {
    let cfg = ServerCfg {
        workers: 2,
        max_sessions: 4,
        staleness: 1,
        ..ServerCfg::default()
    };
    let mut mgr = SessionManager::new(cfg.clone());
    // flood: ~1 decomposition op per stepped round against a 0.05 ceiling
    let flood = mgr
        .create_host(
            "flood",
            1,
            scfg(50, Algo::BKfac, 4000),
            Some(QuotaSpec {
                max_op_rate: 0.05,
                max_mem_mb: 0.0,
            }),
        )
        .unwrap();
    let good = mgr.create_host("good", 1, scfg(11, Algo::BKfac, 20), None).unwrap();
    mgr.run_to_completion(1_000_000).unwrap();

    let f = mgr.session(flood).unwrap();
    assert_eq!(f.status, SessionStatus::Evicted, "flood not evicted");
    assert!(f.steps_done() < 4000, "flood ran to completion anyway");
    assert_eq!(mgr.session(good).unwrap().status, SessionStatus::Done);
    let got = host_fingerprint(&mgr, good);

    let rec = mgr.record();
    assert_eq!(rec.evictions, 1);
    let fr = rec.sessions.iter().find(|s| s.name == "flood").unwrap();
    assert_eq!(fr.evict_reason, "op_rate");
    assert!(fr.throttled_rounds > 0, "ladder skipped the throttle stage");
    let gr = rec.sessions.iter().find(|s| s.name == "good").unwrap();
    assert_eq!(gr.evict_reason, "");
    assert_eq!(gr.throttled_rounds, 0, "compliant tenant was throttled");

    // compliant tenant is bit-identical to its solo run
    let mut solo = SessionManager::new(cfg);
    let id = solo.create_host("solo", 1, scfg(11, Algo::BKfac, 20), None).unwrap();
    solo.run_to_completion(1_000_000).unwrap();
    let want = host_fingerprint(&solo, id);
    assert_eq!(got.0, want.0, "flood eviction perturbed the compliant tenant");
    assert_eq!(got.1, want.1, "rng diverged next to an evicted tenant");
}

/// Memory-ceiling breach evicts with the `memory` reason (pausing a
/// tenant cannot shrink its resident state, so the ladder tops out).
#[test]
fn memory_quota_evicts_with_memory_reason() {
    let mut mgr = SessionManager::new(ServerCfg {
        workers: 1,
        max_sessions: 1,
        staleness: 1,
        ..ServerCfg::default()
    });
    let id = mgr
        .create_host(
            "hog",
            1,
            scfg(77, Algo::BKfac, 4000),
            Some(QuotaSpec {
                max_op_rate: 0.0,
                // far below the session's params+rep footprint
                max_mem_mb: 1e-4,
            }),
        )
        .unwrap();
    mgr.run_to_completion(1_000_000).unwrap();
    assert_eq!(mgr.session(id).unwrap().status, SessionStatus::Evicted);
    let rec = mgr.record();
    assert_eq!(rec.sessions[0].evict_reason, "memory");
    // metrics keep the at-eviction footprint even though the buffers
    // themselves were released
    assert!(rec.sessions[0].resident_mb > 1e-4);
    assert!(mgr.session(id).unwrap().resident_bytes() < 4096, "buffers not released");
    // eviction freed the admission slot (capacity is 1)
    mgr.create_host("next", 1, scfg(78, Algo::BKfac, 4), None)
        .expect("evicted tenant still holds the admission slot");
    mgr.run_to_completion(1_000_000).unwrap();
}

/// With no quotas set and elasticity disabled, the governor must be
/// invisible: identical fairness, shares, and per-session state as the
/// pre-governor configuration (here: the same run twice, one with the
/// bounds spelled out explicitly).
#[test]
fn governor_is_inert_without_quotas() {
    let run = |cfg: ServerCfg| {
        let mut mgr = SessionManager::new(cfg);
        let a = mgr.create_host("a", 2, scfg(31, Algo::BKfac, 16), None).unwrap();
        let b = mgr.create_host("b", 1, scfg(32, Algo::BKfacC, 16), None).unwrap();
        mgr.run_to_completion(1_000_000).unwrap();
        let fa = host_fingerprint(&mgr, a);
        let fb = host_fingerprint(&mgr, b);
        let rec = mgr.record();
        (fa, fb, rec)
    };
    let implicit = run(ServerCfg {
        workers: 2,
        max_sessions: 4,
        staleness: 1,
        ..ServerCfg::default()
    });
    let explicit = run(ServerCfg {
        workers: 2,
        max_sessions: 4,
        staleness: 1,
        workers_min: 2,
        workers_max: 2,
    });
    assert_eq!(implicit.0, explicit.0, "session a diverged");
    assert_eq!(implicit.1, explicit.1, "session b diverged");
    assert_eq!(
        implicit.2.fairness_jain, explicit.2.fairness_jain,
        "scheduler fairness changed under an inert governor"
    );
    for rec in [&implicit.2, &explicit.2] {
        assert_eq!(rec.evictions, 0);
        assert_eq!(rec.grow_events + rec.shrink_events, 0);
        assert_eq!(rec.workers_now, 2);
        for s in &rec.sessions {
            assert_eq!(s.throttled_rounds, 0);
            assert_eq!(s.evict_reason, "");
        }
    }
}

/// Elastic mode: a bursty multi-tenant run completes with the pool
/// always inside `[workers_min, workers_max]`, and the trajectories
/// still bit-match their fixed-pool references (pool size is
/// trajectory-neutral).
#[test]
fn elastic_pool_stays_in_bounds_and_preserves_trajectories() {
    let elastic = ServerCfg {
        workers: 1,
        max_sessions: 4,
        staleness: 1,
        workers_min: 1,
        workers_max: 3,
    };
    let mut mgr = SessionManager::new(elastic);
    let a = mgr.create_host("a", 1, scfg(61, Algo::BKfac, 24), None).unwrap();
    let b = mgr.create_host("b", 1, scfg(62, Algo::BKfacC, 24), None).unwrap();
    mgr.run_to_completion(1_000_000).unwrap();
    let rec = mgr.record();
    assert!(
        (rec.workers_min..=rec.workers_max).contains(&rec.workers_now),
        "pool {} escaped [{},{}]",
        rec.workers_now,
        rec.workers_min,
        rec.workers_max
    );
    for (id, seed, algo) in [(a, 61, Algo::BKfac), (b, 62, Algo::BKfacC)] {
        let got = host_fingerprint(&mgr, id);
        let mut solo = SessionManager::new(ServerCfg {
            workers: 2,
            max_sessions: 1,
            staleness: 1,
            ..ServerCfg::default()
        });
        let sid = solo.create_host("solo", 1, scfg(seed, algo, 24), None).unwrap();
        solo.run_to_completion(1_000_000).unwrap();
        assert_eq!(
            got,
            host_fingerprint(&solo, sid),
            "elastic resize perturbed seed {seed}"
        );
    }
}

/// Quotas survive checkpoint/restore: a restored flood session is still
/// governed (and eventually evicted) in the new server.
#[test]
fn quota_survives_checkpoint_restore() {
    let cfg = ServerCfg {
        workers: 2,
        max_sessions: 2,
        staleness: 1,
        ..ServerCfg::default()
    };
    let mut mgr = SessionManager::new(cfg.clone());
    let id = mgr
        .create_host(
            "q",
            1,
            scfg(91, Algo::BKfac, 4000),
            Some(QuotaSpec {
                max_op_rate: 0.05,
                max_mem_mb: 0.0,
            }),
        )
        .unwrap();
    // checkpoint before the ladder can evict (first window is round 8)
    while mgr.session(id).unwrap().steps_done() < 3 {
        mgr.run_round().unwrap();
        assert!(mgr.round < 1_000_000, "stalled");
    }
    let ck = mgr.checkpoint(id).unwrap();
    assert!(
        ck.to_string_pretty().contains("\"max_op_rate\""),
        "checkpoint lost the quota"
    );
    let mut resumed = SessionManager::new(cfg);
    let rid = resumed.restore(&ck, "q2").unwrap();
    resumed.run_to_completion(1_000_000).unwrap();
    assert_eq!(
        resumed.session(rid).unwrap().status,
        SessionStatus::Evicted,
        "restored session escaped its quota"
    );
    assert_eq!(resumed.record().sessions[0].evict_reason, "op_rate");
}

/// The scripted job driver end-to-end on the shipped smoke file
/// (create / pause / resume / checkpoint / restore / drop).
#[test]
fn job_driver_runs_smoke_file() {
    let path = format!(
        "{}/../examples/jobs_smoke.json",
        env!("CARGO_MANIFEST_DIR")
    );
    let rec = bnkfac::server::driver::run_jobs(&path, None, 500_000).unwrap();
    assert!(rec.total_steps > 0);
    assert!(rec.fairness_jain > 0.0 && rec.fairness_jain <= 1.0 + 1e-12);
    // the restored session ran alongside the original three (one dropped)
    assert_eq!(rec.sessions.len(), 3, "{:?}", rec.sessions);
    for s in &rec.sessions {
        assert_eq!(s.submitted, s.completed, "ops lost for {}", s.name);
    }
}
