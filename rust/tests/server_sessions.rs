//! Integration tests for the multi-tenant session server (DESIGN.md §11):
//! session isolation under the shared pool, checkpoint/resume
//! bit-identity (including an in-flight Brand chain), admission control,
//! fair-share non-starvation under flooding, and graceful shutdown of a
//! service dropped mid-queue. Host substrate only — no artifacts needed.

use std::collections::BTreeMap;
use std::sync::Arc;

use bnkfac::linalg::Mat;
use bnkfac::optim::{Algo, OpRequest, UpdateOp};
use bnkfac::precond::{PrecondCfg, PrecondService};
use bnkfac::runtime::FactorPlan;
use bnkfac::server::{
    FairScheduler, HostSessionCfg, ServerCfg, SessionManager, SessionStatus, Workload,
};
use bnkfac::util::rng::Rng;
use bnkfac::util::threadpool::WorkerPool;
use bnkfac::util::timer::PhaseTimers;

fn scfg(seed: u64, algo: Algo, steps: u64) -> HostSessionCfg {
    HostSessionCfg {
        factors: 2,
        dim: 36,
        rank: 5,
        n_stat: 3,
        grad_cols: 4,
        t_updt: 2,
        algo,
        seed,
        steps,
        rho: 0.95,
        lambda: 0.1,
    }
}

fn host_fingerprint(mgr: &SessionManager, id: u64) -> (Vec<f32>, [u64; 4]) {
    let s = mgr.session(id).expect("session");
    match &s.work {
        Workload::Host(h) => (h.state_vector(), h.rng.state().s),
        _ => panic!("expected host session"),
    }
}

/// Two sessions interleaved on one shared pool must produce EXACTLY the
/// state each produces when run alone — tenant isolation is bit-level.
#[test]
fn interleaved_sessions_bitmatch_solo_runs() {
    let cfg = ServerCfg {
        workers: 2,
        max_sessions: 4,
        staleness: 1,
    };
    let mut mgr = SessionManager::new(cfg.clone());
    let a = mgr.create_host("a", 2, scfg(11, Algo::BKfac, 20)).unwrap();
    let b = mgr.create_host("b", 1, scfg(22, Algo::BKfacC, 20)).unwrap();
    mgr.run_to_completion(100_000).unwrap();
    let fa = host_fingerprint(&mgr, a);
    let fb = host_fingerprint(&mgr, b);

    for (seed, algo, want) in [(11, Algo::BKfac, &fa), (22, Algo::BKfacC, &fb)] {
        let mut solo = SessionManager::new(cfg.clone());
        let id = solo.create_host("solo", 1, scfg(seed, algo, 20)).unwrap();
        solo.run_to_completion(100_000).unwrap();
        let f = host_fingerprint(&solo, id);
        assert_eq!(f.0, want.0, "state diverged for seed {seed}");
        assert_eq!(f.1, want.1, "rng diverged for seed {seed}");
    }

    let rec = mgr.record();
    assert_eq!(rec.total_steps, 40);
    assert!(rec.fairness_jain > 0.0 && rec.fairness_jain <= 1.0 + 1e-12);
    for s in &rec.sessions {
        assert_eq!(s.submitted, s.completed, "ops lost for {}", s.name);
        assert_eq!(s.status, "Done");
    }
}

/// Checkpoint a session mid-run (with a live Brand chain), restore it in
/// a fresh server, and run both to completion: the resumed trajectory
/// must be bit-identical to the uninterrupted one.
#[test]
fn checkpoint_restore_resumes_bit_identically() {
    let cfg = ServerCfg {
        workers: 2,
        max_sessions: 2,
        staleness: 1,
    };
    // uninterrupted reference
    let mut reference = SessionManager::new(cfg.clone());
    let rid = reference
        .create_host("ref", 1, scfg(7, Algo::BKfac, 40))
        .unwrap();
    reference.run_to_completion(100_000).unwrap();
    let want = host_fingerprint(&reference, rid);

    // interrupted run: checkpoint mid-flight, then continue
    let mut mgr = SessionManager::new(cfg.clone());
    let id = mgr.create_host("x", 1, scfg(7, Algo::BKfac, 40)).unwrap();
    while mgr.session(id).unwrap().steps_done() < 21 {
        let st = mgr.run_round().unwrap();
        if st.stepped == 0 {
            std::thread::yield_now();
        }
        assert!(mgr.round < 1_000_000, "stalled before checkpoint point");
    }
    let ckpt = mgr.checkpoint(id).unwrap();
    // the Brand chain must actually be in the checkpoint by step 21
    let text = ckpt.to_string_pretty();
    assert!(text.contains("\"chain\""), "checkpoint lacks chain state");
    mgr.run_to_completion(100_000).unwrap();
    assert_eq!(
        host_fingerprint(&mgr, id),
        want,
        "checkpointing perturbed the continuing run"
    );

    // resumed run in a fresh server
    let mut resumed = SessionManager::new(cfg);
    let rid2 = resumed.restore(&ckpt, "x-resumed").unwrap();
    let at_restore = resumed.session(rid2).unwrap().steps_done();
    assert!((21..40).contains(&at_restore), "bad resume point {at_restore}");
    resumed.run_to_completion(100_000).unwrap();
    assert_eq!(
        host_fingerprint(&resumed, rid2),
        want,
        "resumed trajectory diverged"
    );
}

#[test]
fn admission_control_rejects_past_capacity() {
    let mut mgr = SessionManager::new(ServerCfg {
        workers: 1,
        max_sessions: 2,
        staleness: 1,
    });
    let a = mgr.create_host("a", 1, scfg(1, Algo::BKfac, 8)).unwrap();
    let _b = mgr.create_host("b", 1, scfg(2, Algo::BKfac, 8)).unwrap();
    let err = mgr.create_host("c", 1, scfg(3, Algo::BKfac, 8));
    assert!(err.is_err(), "third session admitted past capacity 2");
    // dropping one frees the slot
    mgr.drop_session(a).unwrap();
    mgr.create_host("c", 1, scfg(3, Algo::BKfac, 8)).unwrap();
    mgr.run_to_completion(100_000).unwrap();
}

#[test]
fn pause_resume_lifecycle() {
    let mut mgr = SessionManager::new(ServerCfg {
        workers: 1,
        max_sessions: 2,
        staleness: 1,
    });
    let id = mgr.create_host("p", 1, scfg(5, Algo::BKfac, 10)).unwrap();
    mgr.run_round().unwrap();
    mgr.pause(id).unwrap();
    let before = mgr.session(id).unwrap().steps_done();
    for _ in 0..5 {
        mgr.run_round().unwrap();
    }
    assert_eq!(
        mgr.session(id).unwrap().steps_done(),
        before,
        "paused session stepped"
    );
    assert_eq!(mgr.session(id).unwrap().status, SessionStatus::Paused);
    mgr.resume(id).unwrap();
    mgr.run_to_completion(100_000).unwrap();
    assert_eq!(mgr.session(id).unwrap().steps_done(), 10);
}

/// One tenant's decomposition chain failing must mark THAT session
/// Failed (error recorded) while every other tenant completes — the
/// failure-containment half of the isolation contract.
#[test]
fn session_failure_is_contained() {
    let mut mgr = SessionManager::new(ServerCfg {
        workers: 1,
        max_sessions: 2,
        staleness: 1,
    });
    let bad = mgr.create_host("bad", 1, scfg(41, Algo::BKfac, 12)).unwrap();
    let good = mgr.create_host("good", 1, scfg(42, Algo::BKfac, 12)).unwrap();
    // poison the bad session's first cell: a Brand op with no predecessor
    // representation errors on the worker and fails the chain
    {
        let svc = mgr.session(bad).unwrap().svc.as_ref().unwrap();
        let req = OpRequest {
            op: UpdateOp::Brand,
            plan: heavy_plan("f0/A", 36),
            gram: None,
            raw_stat: Some(Mat::zeros(36, 2)),
            omega: None,
            corr_idx: None,
            rho: 0.9,
        };
        let mut t = PhaseTimers::new();
        svc.submit(0, req, 0, None, &mut t).unwrap();
    }
    mgr.run_to_completion(100_000).unwrap();
    let b = mgr.session(bad).unwrap();
    assert_eq!(b.status, SessionStatus::Failed, "poisoned session not Failed");
    assert!(b.error.is_some(), "failure not recorded");
    let g = mgr.session(good).unwrap();
    assert_eq!(g.status, SessionStatus::Done, "healthy tenant was taken down");
    assert_eq!(g.steps_done(), 12);
}

fn heavy_plan(id: &str, dim: usize) -> FactorPlan {
    FactorPlan {
        id: id.into(),
        layer: "l".into(),
        kind: "fc".into(),
        side: "A".into(),
        dim,
        rank: 16,
        sketch: 20,
        brand: true,
        n: 4,
        n_crc: 8,
        ops: BTreeMap::new(),
    }
}

fn heavy_rsvd(plan: &FactorPlan, gram: &Mat, rng: &mut Rng) -> OpRequest {
    OpRequest::prepare(UpdateOp::Rsvd, plan, Some(gram), None, 0.9, rng).unwrap()
}

/// A tenant submitting one op must not wait behind another tenant's
/// entire backlog — the scheduler serves the newcomer within its fair
/// share (the end-to-end counterpart of the unit-level proptest).
#[test]
fn fair_share_newcomer_is_not_starved_by_flood() {
    let pool = Arc::new(WorkerPool::new(1));
    let sched = Arc::new(FairScheduler::new());
    sched.register(1, 1);
    sched.register(2, 1);
    let plan = heavy_plan("flood/A", 160);
    let cfg = PrecondCfg {
        workers: 1,
        max_staleness: 64,
    };
    let svc_flood = PrecondService::shared(
        cfg.clone(),
        vec!["flood/A".into()],
        pool.clone(),
        sched.clone(),
        1,
    );
    let svc_small = PrecondService::shared(
        cfg,
        vec!["small/A".into()],
        pool.clone(),
        sched.clone(),
        2,
    );
    let mut rng = Rng::new(3);
    let gram = Mat::psd_with_decay(160, 0.7, &mut rng);
    let mut t = PhaseTimers::new();
    for k in 0..24u64 {
        svc_flood
            .submit(0, heavy_rsvd(&plan, &gram, &mut rng), k, None, &mut t)
            .unwrap();
    }
    let small_plan = heavy_plan("small/A", 160);
    svc_small
        .submit(0, heavy_rsvd(&small_plan, &gram, &mut rng), 0, None, &mut t)
        .unwrap();
    svc_small.drain().unwrap();
    let flood_done = svc_flood
        .counters()
        .completed
        .load(std::sync::atomic::Ordering::Relaxed);
    assert!(
        flood_done < 20,
        "newcomer waited behind {flood_done}/24 flood ops — not fair-shared"
    );
    svc_flood.drain().unwrap();
}

/// Regression (graceful shutdown): dropping a service mid-queue cancels
/// the unstarted backlog and joins the drainer threads instead of
/// leaking them or draining everything first.
#[test]
fn dropping_service_mid_queue_cancels_and_joins() {
    let plan = heavy_plan("big/A", 220);
    let svc = PrecondService::new(
        PrecondCfg {
            workers: 1,
            max_staleness: 32,
        },
        vec!["big/A".into()],
    );
    let mut rng = Rng::new(9);
    let gram = Mat::psd_with_decay(220, 0.7, &mut rng);
    let mut t = PhaseTimers::new();
    for k in 0..12u64 {
        svc.submit(0, heavy_rsvd(&plan, &gram, &mut rng), k, None, &mut t)
            .unwrap();
    }
    let counters = svc.counters().clone();
    drop(svc); // cancels queued ops, then joins the pool threads
    use std::sync::atomic::Ordering::Relaxed;
    let completed = counters.completed.load(Relaxed);
    assert_eq!(counters.submitted.load(Relaxed), 12);
    assert!(
        completed < 12,
        "drop drained the whole backlog instead of cancelling ({completed}/12)"
    );
}

/// Dropping a whole manager with live sessions and queued ops must
/// return promptly (threads joined, queue cancelled) — regression for
/// the drop-ordering contract.
#[test]
fn dropping_manager_mid_run_is_clean() {
    let mut mgr = SessionManager::new(ServerCfg {
        workers: 2,
        max_sessions: 4,
        staleness: 1,
    });
    let big = HostSessionCfg {
        dim: 180,
        rank: 16,
        steps: 50,
        ..scfg(31, Algo::BKfac, 50)
    };
    mgr.create_host("m1", 1, big.clone()).unwrap();
    mgr.create_host("m2", 1, HostSessionCfg { seed: 32, ..big }).unwrap();
    for _ in 0..6 {
        mgr.run_round().unwrap();
    }
    drop(mgr); // must not hang or leak threads
}

/// The scripted job driver end-to-end on the shipped smoke file
/// (create / pause / resume / checkpoint / restore / drop).
#[test]
fn job_driver_runs_smoke_file() {
    let path = format!(
        "{}/../examples/jobs_smoke.json",
        env!("CARGO_MANIFEST_DIR")
    );
    let rec = bnkfac::server::driver::run_jobs(&path, None, 500_000).unwrap();
    assert!(rec.total_steps > 0);
    assert!(rec.fairness_jain > 0.0 && rec.fairness_jain <= 1.0 + 1e-12);
    // the restored session ran alongside the original three (one dropped)
    assert_eq!(rec.sessions.len(), 3, "{:?}", rec.sessions);
    for s in &rec.sessions {
        assert_eq!(s.submitted, s.completed, "ops lost for {}", s.name);
    }
}
