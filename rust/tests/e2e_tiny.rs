//! End-to-end integration over the `tiny` artifacts: every optimizer
//! trains the tiny CNN on synthetic data and the loss must drop.
//!
//! Requires `make artifacts` (artifacts/tiny). Tests share one Runtime
//! (PJRT client) via a process-global, because creating several CPU
//! clients in one process is wasteful.

use std::sync::OnceLock;

use bnkfac::coordinator::{Trainer, TrainerCfg};
use bnkfac::data::{Dataset, DatasetCfg};
use bnkfac::optim::{Algo, Hyper};
use bnkfac::runtime::Runtime;

/// None when the artifact bundle / PJRT runtime is unavailable (offline
/// builds use the vendor xla stub) — each test then skips gracefully.
fn runtime() -> Option<&'static Runtime> {
    static RT: OnceLock<Option<Runtime>> = OnceLock::new();
    RT.get_or_init(|| {
        let dir = format!("{}/artifacts/tiny", env!("CARGO_MANIFEST_DIR"));
        match Runtime::open(dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!(
                    "skipping e2e tests ({e:#}); run `make artifacts` with \
                     the real xla bindings to enable"
                );
                None
            }
        }
    })
    .as_ref()
}

fn tiny_dataset() -> Dataset {
    Dataset::generate(DatasetCfg {
        image: 8,
        n_train: 256,
        n_test: 64,
        noise: 0.25,
        seed: 7,
        ..DatasetCfg::default()
    })
}

/// Fast cadences so every update kind fires within a short run.
fn tiny_hyper() -> Hyper {
    Hyper {
        t_updt: 2,
        t_inv: 8,
        t_brand: 4,
        t_rsvd: 16,
        t_corct: 8,
        brand_layer: Some("fc0".to_string()),
        ..Hyper::default()
    }
}

fn train_with(algo: Algo, epochs: usize) -> Option<(f32, f32, f32)> {
    let rt = runtime()?;
    let ds = tiny_dataset();
    let cfg = TrainerCfg {
        algo,
        hyper: tiny_hyper(),
        seed: 3,
        ..TrainerCfg::default()
    };
    let mut tr = Trainer::new(rt, cfg).unwrap();
    let (loss0, _) = tr.evaluate(&ds).unwrap();
    let log = tr.run(&ds, epochs, 0).unwrap();
    let last = log.eval.last().unwrap();
    Some((loss0, last.test_loss, last.test_acc))
}

#[test]
fn sgd_learns() {
    let Some((l0, l1, acc)) = train_with(Algo::Sgd, 3) else { return };
    assert!(l1 < l0, "SGD loss did not drop: {l0} -> {l1}");
    assert!(acc > 0.15, "SGD acc {acc}");
}

#[test]
fn kfac_exact_learns() {
    let Some((l0, l1, acc)) = train_with(Algo::KfacExact, 3) else { return };
    assert!(l1 < l0, "K-FAC loss did not drop: {l0} -> {l1}");
    assert!(acc > 0.15, "K-FAC acc {acc}");
}

#[test]
fn rkfac_learns() {
    let Some((l0, l1, acc)) = train_with(Algo::RKfac, 3) else { return };
    assert!(l1 < l0, "R-KFAC loss did not drop: {l0} -> {l1}");
    assert!(acc > 0.15, "R-KFAC acc {acc}");
}

#[test]
fn bkfac_learns() {
    let Some((l0, l1, acc)) = train_with(Algo::BKfac, 3) else { return };
    assert!(l1 < l0, "B-KFAC loss did not drop: {l0} -> {l1}");
    assert!(acc > 0.15, "B-KFAC acc {acc}");
}

#[test]
fn brkfac_learns() {
    let Some((l0, l1, acc)) = train_with(Algo::BRKfac, 3) else { return };
    assert!(l1 < l0, "B-R-KFAC loss did not drop: {l0} -> {l1}");
    assert!(acc > 0.15, "B-R-KFAC acc {acc}");
}

#[test]
fn bkfacc_learns() {
    let Some((l0, l1, acc)) = train_with(Algo::BKfacC, 3) else { return };
    assert!(l1 < l0, "B-KFAC-C loss did not drop: {l0} -> {l1}");
    assert!(acc > 0.15, "B-KFAC-C acc {acc}");
}

#[test]
fn seng_learns() {
    let Some((l0, l1, acc)) = train_with(Algo::Seng, 3) else { return };
    assert!(l1 < l0, "SENG loss did not drop: {l0} -> {l1}");
    assert!(acc > 0.15, "SENG acc {acc}");
}

/// Service sync mode (staleness 0) must reproduce the inline trainer
/// trajectory EXACTLY — same losses, same parameters — over a full run.
#[test]
fn precond_sync_service_bitmatches_inline_training() {
    use bnkfac::precond::PrecondCfg;
    let Some(rt) = runtime() else { return };
    let ds = tiny_dataset();
    let run = |precond: Option<PrecondCfg>| {
        let cfg = TrainerCfg {
            algo: Algo::BKfacC,
            hyper: tiny_hyper(),
            seed: 13,
            precond,
            ..TrainerCfg::default()
        };
        let mut tr = Trainer::new(rt, cfg).unwrap();
        let log = tr.run(&ds, 2, 1).unwrap();
        let losses: Vec<f32> = log.train.iter().map(|r| r.loss).collect();
        let mut params: Vec<f32> = Vec::new();
        for name in tr.params.names().to_vec() {
            params.extend_from_slice(tr.params.get(&name).data());
        }
        (losses, params)
    };
    let (inline_losses, inline_params) = run(None);
    let (svc_losses, svc_params) = run(Some(PrecondCfg {
        workers: 2,
        max_staleness: 0,
    }));
    assert_eq!(inline_losses, svc_losses, "loss trajectory diverged");
    assert_eq!(inline_params, svc_params, "parameters diverged");
}

/// Async mode (bounded staleness) must still learn: decompositions trail
/// the optimizer by at most the bound, which perturbs but must not break
/// optimization on the tiny problem.
#[test]
fn precond_async_service_still_learns() {
    use bnkfac::precond::PrecondCfg;
    let Some(rt) = runtime() else { return };
    let ds = tiny_dataset();
    let cfg = TrainerCfg {
        algo: Algo::BKfac,
        hyper: tiny_hyper(),
        seed: 3,
        precond: Some(PrecondCfg {
            workers: 2,
            max_staleness: 2,
        }),
        ..TrainerCfg::default()
    };
    let mut tr = Trainer::new(rt, cfg).unwrap();
    let (l0, _) = tr.evaluate(&ds).unwrap();
    let log = tr.run(&ds, 3, 0).unwrap();
    let last = log.eval.last().unwrap();
    assert!(
        last.test_loss < l0,
        "async B-KFAC loss did not drop: {l0} -> {}",
        last.test_loss
    );
    let svc = log.service.expect("service record attached");
    assert_eq!(svc.submitted, svc.completed, "ops lost");
    assert!(svc.installs > 0, "no decompositions installed");
    // worst case: an op from stat step k must finish by the enforce at
    // k + bound + t_updt, where it is installed ⇒ staleness ≤ bound+t_updt
    assert!(svc.max_staleness_steps <= 4, "staleness runaway: {}", svc.max_staleness_steps);
}

#[test]
fn linear_apply_variant_learns() {
    let Some(rt) = runtime() else { return };
    let ds = tiny_dataset();
    let mut hyper = tiny_hyper();
    hyper.linear_apply = true;
    let cfg = TrainerCfg {
        algo: Algo::BKfac,
        hyper,
        seed: 3,
        ..TrainerCfg::default()
    };
    let mut tr = Trainer::new(rt, cfg).unwrap();
    let (l0, _) = tr.evaluate(&ds).unwrap();
    let log = tr.run(&ds, 3, 0).unwrap();
    let last = log.eval.last().unwrap();
    assert!(
        last.test_loss < l0,
        "B-KFAC(linear apply) loss did not drop: {l0} -> {}",
        last.test_loss
    );
}

#[test]
fn deterministic_given_seed() {
    let Some(rt) = runtime() else { return };
    let ds = tiny_dataset();
    let mk = || {
        let cfg = TrainerCfg {
            algo: Algo::RKfac,
            hyper: tiny_hyper(),
            seed: 11,
            ..TrainerCfg::default()
        };
        let mut tr = Trainer::new(rt, cfg).unwrap();
        let log = tr.run(&ds, 1, 0).unwrap();
        log.eval.last().unwrap().test_loss
    };
    assert_eq!(mk(), mk(), "same seed must reproduce exactly");
}

#[test]
fn probe_produces_rows() {
    use bnkfac::coordinator::probe::ErrorProbe;
    let Some(rt) = runtime() else { return };
    let ds = tiny_dataset();
    let cfg = TrainerCfg {
        algo: Algo::BKfac,
        hyper: tiny_hyper(),
        seed: 5,
        probe_layer: Some("fc0".to_string()),
        ..TrainerCfg::default()
    };
    let mut tr = Trainer::new(rt, cfg).unwrap();
    let mut probe = ErrorProbe::new("fc0");
    probe.run(&mut tr, &ds, 8, 16).unwrap();
    assert!(
        probe.rows.len() >= 12,
        "expected measured rows, got {}",
        probe.rows.len()
    );
    let avg = probe.averages();
    for (i, &m) in avg.iter().enumerate() {
        assert!(m.is_finite() && m >= 0.0, "metric {i} = {m}");
    }
    // an approximate algorithm has nonzero inverse error
    assert!(avg[0] > 1e-6 || avg[1] > 1e-6);
}

#[test]
fn pure_bkfac_is_gram_free_on_brand_layer() {
    // §3.5 "B-KFAC is a low-memory K-FAC": the brand-managed factors
    // must never materialize the dense EA Gram.
    let Some(rt) = runtime() else { return };
    let ds = tiny_dataset();
    let cfg = TrainerCfg {
        algo: Algo::BKfac,
        hyper: tiny_hyper(),
        seed: 3,
        ..TrainerCfg::default()
    };
    let mut tr = Trainer::new(rt, cfg).unwrap();
    let _ = tr.run(&ds, 1, 0).unwrap();
    let fc0 = tr.layers.iter().find(|l| l.spec.name == "fc0").unwrap();
    assert!(fc0.a.gram.is_none(), "fc0/A gram materialized under B-KFAC");
    assert!(fc0.g.gram.is_none(), "fc0/G gram materialized under B-KFAC");
    assert!(fc0.a.rep.is_some(), "fc0/A rep missing");
    // non-brand layers DO keep grams (R-KFAC fallback needs them)
    let conv0 = tr.layers.iter().find(|l| l.spec.name == "conv0").unwrap();
    assert!(conv0.a.gram.is_some());
    // B-R-KFAC keeps the gram even on the brand layer (overwrites need it)
    let cfg = TrainerCfg {
        algo: Algo::BRKfac,
        hyper: tiny_hyper(),
        seed: 3,
        ..TrainerCfg::default()
    };
    let mut tr = Trainer::new(rt, cfg).unwrap();
    let _ = tr.run(&ds, 1, 0).unwrap();
    let fc0 = tr.layers.iter().find(|l| l.spec.name == "fc0").unwrap();
    assert!(fc0.a.gram.is_some(), "B-R-KFAC must keep the gram");
}

#[test]
fn brand_rep_width_is_r_plus_n_after_update() {
    // Alg 4: truncation to r happens just BEFORE each Brand update, so
    // the live representation carries r+n modes ("we use the r + n rank
    // approximation when applying our K-factors inverse", §3.1).
    let Some(rt) = runtime() else { return };
    let ds = tiny_dataset();
    let cfg = TrainerCfg {
        algo: Algo::BKfac,
        hyper: tiny_hyper(),
        seed: 3,
        ..TrainerCfg::default()
    };
    let mut tr = Trainer::new(rt, cfg).unwrap();
    let _ = tr.run(&ds, 1, 0).unwrap(); // enough steps for t_brand=4 to fire
    let fc0 = tr.layers.iter().find(|l| l.spec.name == "fc0").unwrap();
    let plan = &fc0.a.plan;
    assert_eq!(
        fc0.a.rep.as_ref().unwrap().rank(),
        plan.rank + plan.n,
        "post-Brand representation must have rank r+n"
    );
}

#[test]
fn light_and_full_steps_agree_on_loss() {
    // the stat-skipping fast path must be a numerical no-op for the
    // training trajectory: same seeds, T_updt=1 (all full) vs T_updt=2
    // (alternating light) start identically on step 0.
    let Some(rt) = runtime() else { return };
    let ds = tiny_dataset();
    let run_first_loss = |t_updt: usize| {
        let cfg = TrainerCfg {
            algo: Algo::Sgd,
            hyper: Hyper {
                t_updt,
                ..tiny_hyper()
            },
            seed: 9,
            ..TrainerCfg::default()
        };
        let mut tr = Trainer::new(rt, cfg).unwrap();
        let batches = {
            let mut rng = bnkfac::util::rng::Rng::new(1);
            ds.epoch_batches(rt.manifest.config.batch, &mut rng)
        };
        // step 0 is a stat step either way; step 1 differs (light vs full)
        let _ = tr.train_step(&batches[0], 0).unwrap();
        tr.train_step(&batches[1], 0).unwrap().loss
    };
    let full = run_first_loss(1);
    let light = run_first_loss(2);
    assert_eq!(full, light, "light step changed the training trajectory");
}

#[test]
fn brand_layer_all_extends_updates() {
    // brand_layer=None (all) must B-manage every eligible factor,
    // including fc1/A — and still learn.
    let Some(rt) = runtime() else { return };
    let ds = tiny_dataset();
    let mut hyper = tiny_hyper();
    hyper.brand_layer = None;
    let cfg = TrainerCfg {
        algo: Algo::BKfac,
        hyper,
        seed: 3,
        ..TrainerCfg::default()
    };
    let mut tr = Trainer::new(rt, cfg).unwrap();
    let log = tr.run(&ds, 2, 0).unwrap();
    let fc1 = tr.layers.iter().find(|l| l.spec.name == "fc1").unwrap();
    assert!(fc1.a.gram.is_none(), "fc1/A should be brand-managed (gram-free)");
    assert!(log.eval.last().unwrap().test_acc > 0.12);
}

#[test]
fn eval_is_side_effect_free() {
    let Some(rt) = runtime() else { return };
    let ds = tiny_dataset();
    let cfg = TrainerCfg {
        algo: Algo::Sgd,
        hyper: tiny_hyper(),
        seed: 3,
        ..TrainerCfg::default()
    };
    let mut tr = Trainer::new(rt, cfg).unwrap();
    let a = tr.evaluate(&ds).unwrap();
    let b = tr.evaluate(&ds).unwrap();
    assert_eq!(a, b, "evaluate must not mutate model state");
}
