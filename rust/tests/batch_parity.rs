//! Bit-parity suite for batched multi-factor preconditioning
//! (DESIGN.md §17).
//!
//! The batching layer's contract mirrors the kernel-backend contract
//! one level up: grouping ops into batches is allowed to change ONLY
//! dispatch cost, never bits. That holds by construction — the batched
//! kernel entry points run each item's exact solo reduction over its
//! logical extent, and size-class padding lives outside every reduction
//! ("pad the layout, never the reduction") — and these tests enforce it
//! where it would crack:
//!
//! * the `batch_gemm`/`batch_syrk`/`batch_mvp` entry points vs their
//!   solo counterparts, on both backends, across lane/tile-straddling
//!   shapes and padded output buffers;
//! * ANY random partition of a Brand op stream into batches vs the
//!   fully-solo chain (`brand_ea_update_batch` composition
//!   independence), including bucket-boundary shapes;
//! * `OpRequest::execute_batch` vs per-op `execute`, with non-batchable
//!   ops mixed in (the solo-fallback partition);
//! * end to end: a multi-tenant server run with `--batch-factors off`
//!   must checkpoint to the EXACT bytes of the same run with batching
//!   on.

use std::collections::BTreeMap;

use bnkfac::linalg::kernel::{
    self, blocked::Blocked, scalar::Scalar, GemmItem, GemmKind, Kernels, MvpItem, SyrkItem,
};
use bnkfac::linalg::{LowRank, Mat};
use bnkfac::optim::{Algo, OpRequest, UpdateOp};
use bnkfac::precond::batch::{self, BatchMode};
use bnkfac::runtime::FactorPlan;
use bnkfac::server::{HostSessionCfg, ServerCfg, SessionManager};
use bnkfac::util::proptest::check;
use bnkfac::util::rng::Rng;
use bnkfac::util::timer::PhaseTimers;

fn fill32(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.next_gauss_f32()).collect()
}

fn bits32(x: &[f32]) -> Vec<u32> {
    x.iter().map(|v| v.to_bits()).collect()
}

/// Dims biased toward the boundaries that break padded/tiled code:
/// 0, 1, around the 8-lane width, and around small powers of two
/// (bucket edges).
fn dim(rng: &mut Rng) -> usize {
    match rng.next_below(7) {
        0 => 0,
        1 => 1,
        2 => 7 + rng.next_below(3),
        3 => 15 + rng.next_below(3),
        4 => 31 + rng.next_below(3),
        _ => 2 + rng.next_below(24),
    }
}

// ---------------------------------------------------------------------
// Kernel entry points: batch == per-item solo, both backends, bitwise.
// ---------------------------------------------------------------------

struct GemmCase {
    kind: GemmKind,
    m: usize,
    n: usize,
    k: usize,
    /// extra (never-read) padding on the output buffer, as bucket-padded
    /// temporaries carry in production
    pad: usize,
    seed: u64,
}

fn gen_gemm_cases(rng: &mut Rng) -> Vec<GemmCase> {
    let n_items = 1 + rng.next_below(6);
    (0..n_items)
        .map(|_| GemmCase {
            kind: match rng.next_below(3) {
                0 => GemmKind::NN,
                1 => GemmKind::TN,
                _ => GemmKind::NT,
            },
            m: dim(rng),
            n: dim(rng),
            k: dim(rng),
            pad: rng.next_below(9),
            seed: rng.next_u64(),
        })
        .collect()
}

impl std::fmt::Debug for GemmCase {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "GemmCase({:?},m={},n={},k={},pad={},seed={})",
            self.kind, self.m, self.n, self.k, self.pad, self.seed
        )
    }
}

#[test]
fn batch_gemm_bit_matches_solo_on_both_backends() {
    check("batch_gemm == solo gemm", gen_gemm_cases, |cases| {
        for backend in [&Scalar as &dyn Kernels, &Blocked as &dyn Kernels] {
            // operands (identical for solo and batched runs)
            let inputs: Vec<(Vec<f32>, Vec<f32>)> = cases
                .iter()
                .map(|c| {
                    let mut rng = Rng::new(c.seed);
                    let (alen, blen) = match c.kind {
                        GemmKind::NN => (c.m * c.k, c.k * c.n),
                        GemmKind::TN => (c.k * c.m, c.k * c.n),
                        GemmKind::NT => (c.m * c.k, c.n * c.k),
                    };
                    (fill32(&mut rng, alen), fill32(&mut rng, blen))
                })
                .collect();

            // solo: one exact-size zeroed output per item
            let solo: Vec<Vec<f32>> = cases
                .iter()
                .zip(&inputs)
                .map(|(c, (a, b))| {
                    let mut out = vec![0.0f32; c.m * c.n];
                    match c.kind {
                        GemmKind::NN => backend.gemm(c.m, c.n, c.k, a, b, &mut out),
                        GemmKind::TN => backend.gemm_tn(c.m, c.n, c.k, a, b, &mut out),
                        GemmKind::NT => backend.gemm_nt(c.m, c.n, c.k, a, b, &mut out),
                    }
                    out
                })
                .collect();

            // batched: padded zeroed outputs, one call for the group
            let mut padded: Vec<Vec<f32>> = cases
                .iter()
                .map(|c| vec![0.0f32; c.m * c.n + c.pad])
                .collect();
            {
                let mut items: Vec<GemmItem<'_>> = cases
                    .iter()
                    .zip(&inputs)
                    .zip(padded.iter_mut())
                    .map(|((c, (a, b)), out)| GemmItem {
                        kind: c.kind,
                        m: c.m,
                        n: c.n,
                        k: c.k,
                        a,
                        b,
                        c: out,
                    })
                    .collect();
                backend.batch_gemm(&mut items);
            }

            for (i, (c, (s, p))) in cases.iter().zip(solo.iter().zip(&padded)).enumerate() {
                if bits32(s) != bits32(&p[..c.m * c.n]) {
                    return Err(format!(
                        "{} item {i} {c:?}: batched bits differ from solo",
                        backend.name()
                    ));
                }
                if p[c.m * c.n..].iter().any(|&v| v != 0.0) {
                    return Err(format!(
                        "{} item {i} {c:?}: batched call wrote into padding",
                        backend.name()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn batch_syrk_and_mvp_bit_match_solo() {
    check(
        "batch_syrk/mvp == solo",
        |rng: &mut Rng| {
            let n_items = 1 + rng.next_below(5);
            (0..n_items)
                .map(|_| (dim(rng), dim(rng), rng.next_u64()))
                .collect::<Vec<(usize, usize, u64)>>()
        },
        |shapes| {
            for backend in [&Scalar as &dyn Kernels, &Blocked as &dyn Kernels] {
                // syrk: full c = a·aᵀ (both triangles) — the reference is
                // the Mat-level construction (upper panel + mirror copy)
                let mats: Vec<Mat> = shapes
                    .iter()
                    .map(|&(m, k, seed)| {
                        let mut rng = Rng::new(seed);
                        Mat::from_vec(m, k, fill32(&mut rng, m * k))
                    })
                    .collect();
                let solo: Vec<Mat> = mats.iter().map(|a| a.syrk()).collect();
                let mut outs: Vec<Vec<f32>> =
                    shapes.iter().map(|&(m, _, _)| vec![0.0f32; m * m]).collect();
                {
                    let mut items: Vec<SyrkItem<'_>> = mats
                        .iter()
                        .zip(outs.iter_mut())
                        .map(|(a, c)| SyrkItem {
                            m: a.rows,
                            k: a.cols,
                            a: &a.data,
                            c,
                        })
                        .collect();
                    backend.batch_syrk(&mut items);
                }
                for (i, (s, p)) in solo.iter().zip(&outs).enumerate() {
                    if bits32(&s.data) != bits32(p) {
                        return Err(format!(
                            "{} syrk item {i}: batched bits differ from Mat::syrk",
                            backend.name()
                        ));
                    }
                }

                // mvp: y = a·x vs solo gemv
                let xs: Vec<Vec<f32>> = shapes
                    .iter()
                    .map(|&(_, k, seed)| fill32(&mut Rng::new(seed ^ 1), k))
                    .collect();
                let solo_y: Vec<Vec<f32>> = mats
                    .iter()
                    .zip(&xs)
                    .map(|(a, x)| {
                        let mut y = vec![0.0f32; a.rows];
                        backend.gemv(a.rows, a.cols, &a.data, x, &mut y);
                        y
                    })
                    .collect();
                let mut ys: Vec<Vec<f32>> =
                    mats.iter().map(|a| vec![0.0f32; a.rows]).collect();
                {
                    let mut items: Vec<MvpItem<'_>> = mats
                        .iter()
                        .zip(&xs)
                        .zip(ys.iter_mut())
                        .map(|((a, x), y)| MvpItem {
                            r: a.rows,
                            n: a.cols,
                            a: &a.data,
                            x,
                            y,
                        })
                        .collect();
                    backend.batch_mvp(&mut items);
                }
                for (i, (s, p)) in solo_y.iter().zip(&ys).enumerate() {
                    if bits32(s) != bits32(p) {
                        return Err(format!(
                            "{} mvp item {i}: batched bits differ from gemv",
                            backend.name()
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// Brand pipeline: any partition of an op stream → identical bits.
// ---------------------------------------------------------------------

/// One factor's chain setup: dimension, kept rank, arrival width.
fn gen_factors(rng: &mut Rng) -> (Vec<(usize, usize, usize)>, u64, usize) {
    let n_factors = 2 + rng.next_below(5);
    let factors = (0..n_factors)
        .map(|_| {
            // r*n / r*r / n*n straddle bucket (power-of-two) boundaries
            // across this spread — the padded-layout regression surface
            let r = 2 + rng.next_below(7);
            let n = 1 + rng.next_below(4);
            let d = r + n + 2 + rng.next_below(20);
            (d, r, n)
        })
        .collect();
    (factors, rng.next_u64(), 1 + rng.next_below(4))
}

#[test]
fn brand_chain_bit_identical_under_any_partition() {
    check(
        "brand batch partition independence",
        gen_factors,
        |(factors, seed, rounds)| {
            let rho = 0.95f32;
            let mut data_rng = Rng::new(*seed);
            // initial reps + per-round arrivals, shared by both runs
            let init: Vec<LowRank> = factors
                .iter()
                .map(|&(d, r, _)| {
                    let g = Mat::gauss(d, r, 1.0, &mut data_rng);
                    LowRank::from_eigh(&g.syrk().eigh(), r)
                })
                .collect();
            let arrivals: Vec<Vec<Mat>> = (0..*rounds)
                .map(|_| {
                    factors
                        .iter()
                        .map(|&(d, _, n)| Mat::gauss(d, n, 1.0, &mut data_rng))
                        .collect()
                })
                .collect();

            // solo chain: one factor at a time (batch of one)
            let mut solo = init.clone();
            for round in arrivals.iter() {
                for (i, a) in round.iter().enumerate() {
                    let r = factors[i].1;
                    solo[i] = solo[i].brand_ea_update(a, rho, r);
                }
            }

            // batched chain: per round, a seed-derived random partition
            // of the factor set into groups, each group one batch call
            let mut part_rng = Rng::new(seed ^ 0xB47C4);
            let mut batched = init.clone();
            for round in arrivals.iter() {
                let mut order: Vec<usize> = (0..factors.len()).collect();
                // random order, then random group boundaries
                for i in (1..order.len()).rev() {
                    order.swap(i, part_rng.next_below(i + 1));
                }
                let mut idx = 0;
                while idx < order.len() {
                    let take = 1 + part_rng.next_below(order.len() - idx);
                    let group = &order[idx..idx + take];
                    let items: Vec<(&LowRank, &Mat, f32, usize)> = group
                        .iter()
                        .map(|&i| (&batched[i], &round[i], rho, factors[i].1))
                        .collect();
                    let outs = LowRank::brand_ea_update_batch(&items);
                    for (&i, out) in group.iter().zip(outs) {
                        batched[i] = out;
                    }
                    idx += take;
                }
            }

            for (i, (s, b)) in solo.iter().zip(&batched).enumerate() {
                if bits32(&s.u.data) != bits32(&b.u.data) || bits32(&s.d) != bits32(&b.d) {
                    return Err(format!(
                        "factor {i} {:?}: batched chain diverged from solo chain",
                        factors[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------------
// OpRequest::execute_batch == per-op execute (incl. solo fallback).
// ---------------------------------------------------------------------

fn plan(layer: &str, dim: usize, rank: usize, n: usize) -> FactorPlan {
    FactorPlan {
        id: format!("{layer}/A"),
        layer: layer.into(),
        kind: "fc".into(),
        side: "A".into(),
        dim,
        rank,
        sketch: rank + 4,
        brand: true,
        n,
        n_crc: (rank / 2).max(1),
        ops: BTreeMap::new(),
    }
}

#[test]
fn execute_batch_matches_solo_execute() {
    let mut rng = Rng::new(0xEB);
    let mut t = PhaseTimers::new();
    // A mixed group: Brand, BrandCorrect (batchable) and ExactEvd (solo
    // fallback inside execute_batch), heterogeneous shapes.
    let specs = [
        (UpdateOp::Brand, 24usize, 6usize, 3usize),
        (UpdateOp::BrandCorrect, 17, 5, 2),
        (UpdateOp::ExactEvd, 12, 4, 2),
        (UpdateOp::Brand, 9, 3, 1),
    ];
    let mut reqs: Vec<(OpRequest, Option<LowRank>)> = Vec::new();
    for (i, &(op, d, r, n)) in specs.iter().enumerate() {
        let p = plan(&format!("f{i}"), d, r, n);
        let gram = Mat::psd_with_decay(d, 0.7, &mut rng);
        let stat = Mat::gauss(d, n, 1.0, &mut rng);
        let prev = LowRank::from_eigh(&gram.eigh(), r);
        let req = OpRequest::prepare(op, &p, Some(&gram), Some(&stat), 0.95, &mut rng)
            .expect("non-None op");
        reqs.push((req, Some(prev)));
    }

    let solo: Vec<Option<LowRank>> = reqs
        .iter()
        .map(|(req, prev)| req.clone().execute(prev.clone(), None, &mut t).unwrap())
        .collect();
    let batched = OpRequest::execute_batch(reqs, None, &mut t);

    for (i, (s, b)) in solo.iter().zip(batched).enumerate() {
        let b = b.unwrap();
        match (s, b) {
            (Some(s), Some(b)) => {
                assert_eq!(
                    bits32(&s.u.data),
                    bits32(&b.u.data),
                    "op {i} ({:?}): U bits differ",
                    specs[i].0
                );
                assert_eq!(bits32(&s.d), bits32(&b.d), "op {i}: d bits differ");
            }
            (None, None) => {}
            (s, b) => panic!(
                "op {i}: presence mismatch solo={} batched={}",
                s.is_some(),
                b.is_some()
            ),
        }
    }
}

// ---------------------------------------------------------------------
// Bucket padding: counters move, boundary shapes stay correct.
// ---------------------------------------------------------------------

#[test]
fn bucket_padding_counts_fill_and_preserves_boundary_shapes() {
    // bucket_len is next_power_of_two and feeds the fill counters
    let (_, l0, p0) = kernel::counters::batch_snapshot();
    assert_eq!(kernel::bucket_len(5), 8);
    assert_eq!(kernel::bucket_len(8), 8);
    assert_eq!(kernel::bucket_len(9), 16);
    let (_, l1, p1) = kernel::counters::batch_snapshot();
    assert!(l1 >= l0 + 5 + 8 + 9, "logical counter did not advance");
    assert!(p1 >= p0 + 8 + 8 + 16, "padded counter did not advance");

    // Regression for the padded-layout construction: shapes whose
    // temporaries straddle power-of-two boundaries (r*n = 15, 16, 17 …)
    // must produce the exact dense-EVD reconstruction — a one-off-error
    // into padding would corrupt the trailing logical elements.
    let mut rng = Rng::new(0xBADu64);
    for &(r, n) in &[(5usize, 3usize), (4, 4), (8, 2), (3, 5), (7, 3)] {
        let d = r + n + 12;
        let g = Mat::gauss(d, r, 1.0, &mut rng);
        let lr = LowRank::from_eigh(&g.syrk().eigh(), r);
        let a = Mat::gauss(d, n, 1.0, &mut rng);
        let upd = lr.brand_update(&a);
        let want = lr.to_dense().add(&a.syrk());
        let err = upd.to_dense().rel_err(&want);
        assert!(
            err < 1e-4,
            "brand_update wrong at bucket-boundary shape r={r} n={n}: rel_err={err}"
        );
    }
}

// ---------------------------------------------------------------------
// End to end: batched and unbatched server runs checkpoint identically.
// ---------------------------------------------------------------------

fn scfg(seed: u64, algo: Algo, steps: u64) -> HostSessionCfg {
    HostSessionCfg {
        factors: 4,
        dim: 28,
        rank: 5,
        n_stat: 3,
        grad_cols: 4,
        t_updt: 2,
        algo,
        seed,
        steps,
        rho: 0.95,
        lambda: 0.1,
        policy: None,
    }
}

/// The tentpole acceptance check: a multi-tenant async run (many small
/// factors per session, staleness ≥ 1 so the shared-pool batched drain
/// path is exercised) with `--batch-factors off` must serialize to the
/// EXACT checkpoint bytes of the same run with grouping on.
#[test]
fn checkpoints_byte_identical_batched_vs_off() {
    let run = |mode: BatchMode| -> String {
        batch::set_mode(mode);
        let mut mgr = SessionManager::new(ServerCfg {
            workers: 2,
            max_sessions: 4,
            staleness: 1,
            ..ServerCfg::default()
        });
        let a = mgr
            .create_host("a", 1, scfg(31, Algo::BKfac, 24), None)
            .unwrap();
        let b = mgr
            .create_host("b", 2, scfg(32, Algo::BKfacC, 24), None)
            .unwrap();
        mgr.run_to_completion(1_000_000).unwrap();
        let ja = mgr.checkpoint(a).unwrap().to_string_pretty();
        let jb = mgr.checkpoint(b).unwrap().to_string_pretty();
        format!("{ja}\n{jb}")
    };
    let off = run(BatchMode::Off);
    let on = run(BatchMode::Max(4));
    batch::set_mode(BatchMode::Auto);
    assert!(
        off.len() > 200,
        "checkpoint suspiciously small — workload did not run"
    );
    assert_eq!(
        off, on,
        "server checkpoints differ between batched and unbatched drains"
    );
}
