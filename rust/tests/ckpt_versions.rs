//! Checkpoint version-ladder coverage (ISSUE 5 satellite).
//!
//! The checkpoint format has walked v1.0 → v1.1 (`state.seng`, SENG
//! buffers) → v1.2 (top-level `quota`, governor ceilings) → v1.3
//! (`cfg.policy` + `state.policy`, the `algo = auto` decision engine);
//! every added section is OPTIONAL to the decoder, so older checkpoints
//! must keep decoding under the v1.3 reader forever. Two angles pin
//! that down:
//!
//! * **committed fixtures** (`tests/fixtures/ckpt_v1_{0,1}_host.json`):
//!   hand-written pre-quota checkpoints that must decode, restore, and
//!   run to completion — if a future format change adds a *required*
//!   key, these fail loudly instead of silently breaking every deployed
//!   checkpoint;
//! * **downgraded live checkpoints**: a mid-run v1.2 checkpoint with the
//!   `quota` (and, for models, `seng`) sections stripped and the version
//!   stamp rewritten must resume BIT-IDENTICALLY to the untouched one —
//!   the quota-absent / seng-absent decode paths feed the exact same
//!   trajectory.

use std::sync::OnceLock;

use bnkfac::coordinator::TrainerCfg;
use bnkfac::data::{Dataset, DatasetCfg};
use bnkfac::optim::Algo;
use bnkfac::runtime::Runtime;
use bnkfac::server::{ckpt, HostSessionCfg, QuotaSpec, ServerCfg, SessionManager};
use bnkfac::util::ser::Json;

fn server_cfg() -> ServerCfg {
    ServerCfg {
        workers: 2,
        max_sessions: 4,
        staleness: 1,
        ..ServerCfg::default()
    }
}

/// Clone a checkpoint with a rewritten version stamp and (optionally)
/// the v1.2 `quota` section removed — i.e. the bytes a pre-v1.3 writer
/// would have produced for the same state. Pre-1.3 writers also never
/// emitted the `cfg.policy` / `state.policy` keys, so those are always
/// stripped (a no-op beyond key presence for fixed-algo sessions, which
/// carry them as explicit nulls under the current writer).
fn downgrade(j: &Json, version: f64, strip_quota: bool) -> Json {
    match j.clone() {
        Json::Obj(mut m) => {
            m.insert("version".into(), Json::Num(version));
            if strip_quota {
                m.remove("quota");
            }
            if let Some(Json::Obj(cfg)) = m.get_mut("cfg") {
                cfg.remove("policy");
            }
            if let Some(Json::Obj(st)) = m.get_mut("state") {
                st.remove("policy");
            }
            Json::Obj(m)
        }
        _ => panic!("checkpoint is not an object"),
    }
}

/// Restore a host checkpoint into a fresh server, run to completion,
/// and return the final checkpoint.
fn finish_host(j: &Json) -> Json {
    let mut mgr = SessionManager::new(server_cfg());
    let id = mgr.restore(j, "resumed").expect("restore");
    mgr.run_to_completion(1_000_000).expect("run");
    mgr.checkpoint(id).expect("final checkpoint")
}

// ------------------------------------------------------------- fixtures

#[test]
fn committed_v10_and_v11_fixtures_decode_restore_and_complete() {
    let dir = format!("{}/tests/fixtures", env!("CARGO_MANIFEST_DIR"));
    for (file, name, start_step) in [
        ("ckpt_v1_0_host.json", "legacy10", 4u64),
        ("ckpt_v1_1_host.json", "legacy11", 2u64),
    ] {
        let text = std::fs::read_to_string(format!("{dir}/{file}"))
            .unwrap_or_else(|e| panic!("{file}: {e}"));
        let j = Json::parse(&text).unwrap_or_else(|e| panic!("{file}: {e}"));
        let r = ckpt::decode_host(&j).unwrap_or_else(|e| panic!("{file}: {e:#}"));
        // the quota-absent path: pre-1.2 checkpoints decode to no quota
        assert!(r.quota.is_none(), "{file}: pre-1.2 checkpoint grew a quota");
        assert_eq!(r.name, name, "{file}");
        assert_eq!(r.session.step, start_step, "{file}");
        assert_eq!(r.session.cfg.seed, 0x2a, "{file}");

        // restore under the current reader and run to completion
        let mut mgr = SessionManager::new(server_cfg());
        let id = mgr.restore(&j, "").unwrap_or_else(|e| panic!("{file}: {e:#}"));
        mgr.run_to_completion(1_000_000).unwrap();
        assert_eq!(mgr.session(id).unwrap().steps_done(), 4, "{file}");

        // re-encoding stamps the CURRENT version and an explicit null
        // quota — the ladder only ever climbs
        let ck = mgr.checkpoint(id).unwrap();
        assert_eq!(
            ck.get("version").and_then(|v| v.as_f64()),
            Some(ckpt::VERSION),
            "{file}"
        );
        assert_eq!(ck.get("quota"), Some(&Json::Null), "{file}");
    }
}

// ------------------------------------------------- downgraded live ckpts

/// A mid-run v1.2 host checkpoint downgraded to v1.0/v1.1 (quota
/// stripped) must decode with no quota and resume bit-identically to
/// the untouched v1.2 checkpoint.
#[test]
fn downgraded_host_checkpoint_resumes_bit_identically() {
    let quota = Some(QuotaSpec {
        // loose ceilings: present in the checkpoint, never enforced
        max_op_rate: 1000.0,
        max_mem_mb: 4096.0,
    });
    let mut mgr = SessionManager::new(server_cfg());
    let id = mgr
        .create_host(
            "a",
            2,
            HostSessionCfg {
                seed: 0x77,
                steps: 24,
                ..HostSessionCfg::default()
            },
            quota,
        )
        .unwrap();
    while mgr.session(id).unwrap().steps_done() < 10 {
        let st = mgr.run_round().unwrap();
        if st.stepped == 0 {
            std::thread::yield_now();
        }
        assert!(mgr.round < 1_000_000, "stalled before mid-run checkpoint");
    }
    let ck12 = mgr.checkpoint(id).unwrap();
    assert_ne!(
        ck12.get("quota"),
        Some(&Json::Null),
        "v1.2 checkpoint must persist the quota"
    );

    // current writer stamps v1.3 with explicit-null policy sections for
    // fixed-algo sessions
    assert_eq!(ck12.get("version").and_then(|v| v.as_f64()), Some(ckpt::VERSION));
    assert_eq!(
        ck12.get("state").and_then(|s| s.get("policy")),
        Some(&Json::Null),
        "fixed-algo v1.3 checkpoint must carry an explicit null policy"
    );

    let ck10 = downgrade(&ck12, 1.0, true);
    let ck11 = downgrade(&ck12, 1.1, true);
    // a v1.2 writer kept the quota but had no policy keys at all
    let ck12d = downgrade(&ck12, 1.2, false);
    assert!(ckpt::decode_host(&ck10).unwrap().quota.is_none());
    assert!(ckpt::decode_host(&ck11).unwrap().quota.is_none());
    assert!(ckpt::decode_host(&ck12d).unwrap().quota.is_some());
    let q = ckpt::decode_host(&ck12).unwrap().quota.unwrap();
    assert_eq!(q.max_op_rate, 1000.0);

    let f12 = finish_host(&ck12);
    let f10 = finish_host(&ck10);
    let f11 = finish_host(&ck11);
    let f12d = finish_host(&ck12d);
    assert_eq!(f10.get("cfg"), f12.get("cfg"), "v1.0 resume changed the cfg");
    assert_eq!(
        f10.get("state"),
        f12.get("state"),
        "v1.0 resume diverged bit-wise from the v1.3 resume"
    );
    assert_eq!(
        f11.get("state"),
        f12.get("state"),
        "v1.1 resume diverged bit-wise from the v1.3 resume"
    );
    assert_eq!(
        f12d.get("state"),
        f12.get("state"),
        "v1.2 resume diverged bit-wise from the v1.3 resume"
    );
    // quota re-registration on restore: only the v1.2+ lineages keep it
    assert_eq!(f10.get("quota"), Some(&Json::Null));
    assert_eq!(f11.get("quota"), Some(&Json::Null));
    assert_ne!(f12.get("quota"), Some(&Json::Null));
    assert_ne!(f12d.get("quota"), Some(&Json::Null));
}

// ------------------------------------- model ladder (artifact-gated)

fn runtime() -> Option<&'static Runtime> {
    static RT: OnceLock<Option<Runtime>> = OnceLock::new();
    RT.get_or_init(|| {
        let dir = format!("{}/artifacts/tiny", env!("CARGO_MANIFEST_DIR"));
        match Runtime::open(dir) {
            Ok(rt) => Some(rt),
            Err(e) => {
                eprintln!("skipping model ckpt-ladder tests ({e:#})");
                None
            }
        }
    })
    .as_ref()
}

fn tiny_dataset(rt: &Runtime) -> Dataset {
    Dataset::generate(DatasetCfg {
        image: rt.manifest.config.image,
        channels: rt.manifest.config.channels,
        n_classes: rt.manifest.config.n_classes,
        n_train: 64,
        n_test: 32,
        seed: 77,
        ..DatasetCfg::default()
    })
}

/// Strip the sections a v1.0 model writer did not emit: `state.seng`
/// and `cfg.seng` (SENG hyperparameters arrived with v1.1).
fn strip_seng(j: &Json) -> Json {
    let Json::Obj(mut m) = j.clone() else {
        panic!("checkpoint is not an object")
    };
    m.insert("version".into(), Json::Num(1.0));
    m.remove("quota");
    if let Some(Json::Obj(st)) = m.get_mut("state") {
        st.remove("seng");
    }
    if let Some(Json::Obj(cfg)) = m.get_mut("cfg") {
        cfg.remove("seng");
    }
    Json::Obj(m)
}

/// The seng-absent path: a v1.0-shaped model checkpoint (no `seng`
/// sections, no `quota`) decodes to empty SENG buffers and default SENG
/// hyperparameters, and — for a non-SENG trainer, whose buffers are
/// empty anyway — resumes bit-identically to the untouched v1.2 one.
#[test]
fn seng_absent_model_checkpoint_decodes_and_resumes() {
    let Some(rt) = runtime() else { return };
    let cfg = server_cfg();
    let tcfg = TrainerCfg {
        algo: Algo::BKfac,
        seed: 13,
        eval_every: 0,
        ..TrainerCfg::default()
    };
    let mut mgr = SessionManager::with_runtime(cfg.clone(), rt);
    let id = mgr
        .create_model("m", 1, tcfg, tiny_dataset(rt), 12, None)
        .unwrap();
    while mgr.session(id).unwrap().steps_done() < 5 {
        let st = mgr.run_round().unwrap();
        if st.stepped == 0 {
            std::thread::yield_now();
        }
        assert!(mgr.round < 1_000_000, "stalled before checkpoint");
    }
    let ck12 = mgr.checkpoint(id).unwrap();
    let ck10 = strip_seng(&ck12);

    let r = ckpt::decode_model(&ck10).expect("seng-absent model checkpoint decodes");
    assert!(r.quota.is_none());
    assert!(r.state.seng_diag.is_empty() && r.state.seng_velocity.is_empty());
    let dflt = TrainerCfg::default();
    assert_eq!(r.cfg.seng_damping, dflt.seng_damping);
    assert_eq!(r.cfg.seng_momentum, dflt.seng_momentum);

    let finish_model = |j: &Json| -> Json {
        let mut m = SessionManager::with_runtime(cfg.clone(), rt);
        let rid = m.restore_model(j, "r", tiny_dataset(rt)).expect("restore");
        m.run_to_completion(1_000_000).unwrap();
        m.checkpoint(rid).unwrap()
    };
    let f12 = finish_model(&ck12);
    let f10 = finish_model(&ck10);
    assert_eq!(
        f10.get("state"),
        f12.get("state"),
        "seng-absent resume diverged bit-wise"
    );
    assert_eq!(f10.get("pipeline"), f12.get("pipeline"));
}
